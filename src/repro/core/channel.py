"""Channels, connections, and the RPC data path (§4.2, Fig. 6).

A server ``open``s a channel (registered with the orchestrator under a
hierarchical name); clients ``connect`` and receive a ``Connection`` whose
shared-memory heap holds both the RPC argument objects *and* the request
descriptor ring. An RPC is: client writes a descriptor (fn id, GlobalAddr
of the args, seal index, flags) into the ring and the server — polling
under the §5.8 adaptive busy-wait policy — dereferences the pointer
directly. **No argument bytes ever move**; that is the paper's entire
point.

The ring slots live in heap bytes (so the fallback transport can migrate
them like any page) but are accessed through a preallocated NumPy
structured-array view (``DescriptorRing``): every slot field is a strided
view over the heap buffer, so the steady-state path performs **zero
``struct`` repacking and zero Python-level byte copies** — a post is one
record store, a completion is two word stores, a poll is one word load.
Rings are daemon-owned and never sealed, so the checked load/store path
would only add cost without adding safety — same reasoning as the paper
running the descriptor buffer outside the seal machinery.

Threading model: one client per connection (the paper's model — each
client gets its own connection+ring); the server may serve many
connections from one listen loop. ``serve_once`` sweeps every ring's head
state with a single vectorized compare; ``serve_many`` drains every ready
slot found until the channel is idle.
"""

from __future__ import annotations

import threading
import time
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from . import addr as gaddr
from .errors import ChannelError, DeadlineExceeded, Overloaded, \
    SandboxViolation, SealViolation, WaitTimeout
from .heap import SharedHeap
from .orchestrator import Orchestrator
from .sandbox import SandboxManager
from .scope import Scope, ScopePool, create_scope, implicit_scope
from .seal import SealManager
from ..configs.global_config import ReproConfig, global_config

# Lazily-bound marshalling module (core/marshal.py imports this module for
# the flag constants, so the import direction must stay marshal → channel;
# the first invoke binds it here and the hot path pays one global load).
_marshal = None


def _get_marshal():
    global _marshal
    if _marshal is None:
        from . import marshal
        _marshal = marshal
    return _marshal

# Request-ring slot layout: seq, fn, flags, arg, seal_idx, ret, state,
# status, scope_start, scope_count (the receiver sandboxes exactly the
# scope the sender used — §5.2). Little-endian, no padding: byte-for-byte
# identical to the historical ``struct`` format "<QIIQQQIIII" (56 bytes),
# so a ring page migrated by the fallback transport is readable by either
# implementation.
RING_DTYPE = np.dtype([
    ("seq", "<u8"),
    ("fn", "<u4"),
    ("flags", "<u4"),
    ("arg", "<u8"),
    ("seal_idx", "<u8"),
    ("ret", "<u8"),
    ("state", "<u4"),
    ("status", "<u4"),
    ("scope_start", "<u4"),
    ("scope_count", "<u4"),
])
RING_SLOT_BYTES = RING_DTYPE.itemsize  # 56

# u64-word aliasing of a slot: 7 words; fields that share a word are
# packed little-endian (low half first).
_SLOT_WORDS = RING_SLOT_BYTES // 8
_W_RET = 4       # ret
_W_STATE = 5     # state (low 32) | status (high 32)
_M32 = 0xFFFFFFFF

# slot states
R_EMPTY = 0
R_REQ = 1
R_DONE = 2
R_ERR = 3

# flags
F_SEALED = 1 << 0
F_SANDBOXED = 1 << 1
F_TYPED = 1 << 2     # arg is a typed marshalled request (core/marshal.py)
F_BYVAL = 1 << 3     # typed request travelled by value (serial-encoded)
F_DEADLINE = 1 << 4  # the slot's ret word carries the request deadline
                     # (µs, monotonic clock) at post time; the receiver
                     # drops expired requests with E_DEADLINE before
                     # touching the arguments
F_STREAM = 1 << 5    # streaming reply: the handler is a generator and the
                     # reply is a chain of generation-tagged chunks hung
                     # off the request's stream anchor (core/marshal.py);
                     # the slot completes only when the chain ends

# RPC status codes
OK = 0
E_UNSEALED = 1      # receiver demanded a seal, region was not sealed
E_SANDBOX = 2       # sandbox violation while processing (SIGSEGV→error)
E_NOFUNC = 3
E_EXCEPTION = 4
E_DEADLINE = 5      # request deadline lapsed (dropped server-side, or a
                    # handler raised DeadlineExceeded mid-flight)
E_OVERLOAD = 6      # admission control shed the request pre-dispatch
                    # (§5.4); the reply's ret word carries the suggested
                    # retry-after in µs — the shed cost one descriptor
                    # word, never a handler


def _now_us() -> int:
    """Descriptor deadline clock: µs on the monotonic clock (all
    endpoints are in-process, so one clock serves the whole 'cluster')."""
    return int(time.monotonic() * 1e6)


# client-side wait: GIL-yield polls spent before the §5.8 policy back-off
# kicks in (a reply that lands promptly never pays a real sleep)
_WAIT_SPIN_POLLS = 256


class BusyWaitPolicy:
    """§5.8 adaptive busy-wait: no sleep below 25% load, 5µs between 25–50%,
    150µs above 50%. "Load" is approximated by the poll duty cycle over a
    sliding window. A fixed sleep can be forced for the Fig. 13 sweep."""

    def __init__(self, fixed_sleep_us: Optional[float] = None,
                 window: int = 256):
        self.fixed = fixed_sleep_us
        self.window = window
        self._hits = 0
        self._polls = 0

    def record(self, found_work: bool) -> None:
        self._polls += 1
        if found_work:
            self._hits += 1
        if self._polls >= self.window:
            self._hits //= 2
            self._polls //= 2

    def delay_s(self) -> float:
        """The back-off the policy prescribes right now, in seconds
        (0.0 = spin). Callers may spend it blocked on a doorbell instead
        of a blind nap — the budget is the same either way."""
        if self.fixed is not None:
            return self.fixed * 1e-6 if self.fixed > 0 else 0.0
        load = self._hits / max(1, self._polls)
        if load < 0.25:
            return 0.0
        return 5e-6 if load < 0.5 else 150e-6

    def sleep(self) -> None:
        # time.sleep(0) is a bare GIL yield — the CPython stand-in for
        # "no sleep, keep spinning" (a hardware spin would starve the
        # other thread of the interpreter lock entirely).
        time.sleep(self.delay_s())


def _serve_event_loop(serve_pending: Callable[[], int],
                      sweep_once: Callable[[], int],
                      channels, policy: BusyWaitPolicy,
                      stop: threading.Event, ev: threading.Event) -> None:
    """The one §5.8 busy-wait/doorbell protocol, shared by
    ``Channel.listen`` (one channel) and ``ServerLoop.run`` (many).

    The policy-prescribed back-off is spent blocked on the doorbell event
    rather than in a blind nap: a post that lands while the server is
    backing off wakes it immediately, so the high-load 150µs budget
    bounds the wait instead of gating every batch. The clear → park →
    re-sweep → wait sequence is race-sensitive (a post may land between
    the clear and the park flag), so it lives here exactly once.
    """
    while not stop.is_set():
        n = serve_pending()
        policy.record(n > 0)
        if n == 0:
            delay = policy.delay_s()
            if delay <= 0:
                time.sleep(0)  # spin, but yield the GIL
                continue
            ev.clear()
            for ch in channels:
                ch._parked = True
            # re-check after parking: a post may have raced the clear
            # (posts from here on see _parked and ring the doorbell)
            if sweep_once():
                for ch in channels:
                    ch._parked = False
                policy.record(True)
                continue
            ev.wait(delay)
            for ch in channels:
                ch._parked = False


class DescriptorRing:
    """SPSC descriptor ring: a structured-dtype view over heap bytes.

    ``arr`` is the slot table; each field (``seq``, ``fn``, ``state``, …)
    is also exposed as a strided NumPy view so callers can do field-sliced
    loads/stores (``ring.seq[slot] = …``) or vectorized sweeps
    (``ring.state == R_REQ``) with no repacking. The hottest scalar ops
    additionally go through a u64 word alias of the same bytes: one load
    polls state+status, one store publishes them.
    """

    __slots__ = ("heap", "capacity", "head", "start_page", "arr",
                 "seq", "fn", "flags", "arg", "seal_idx", "ret",
                 "state", "status", "scope_start", "scope_count",
                 "_words", "_w0")

    def __init__(self, heap: SharedHeap, capacity: int = 256):
        self.heap = heap
        self.capacity = capacity
        self.head = 1  # next slot the server will serve (seq starts at 1)
        nbytes = capacity * RING_SLOT_BYTES
        pages = (nbytes + heap.page_size - 1) // heap.page_size
        self.start_page = heap.alloc_pages(pages, owner=0)
        base = self.start_page * heap.page_size
        # raw views — daemon-owned, never sealed (see module docstring)
        self.arr = heap.buf[base : base + nbytes].view(RING_DTYPE)
        for name in RING_DTYPE.names:
            setattr(self, name, self.arr[name])
        # u64 word alias (page-aligned base, so always 8-aligned)
        self._words = heap.buf.data.cast("Q")
        self._w0 = base // 8

    # -- hot-path scalar ops -------------------------------------------
    def post(self, slot: int, seq: int, fn: int, flags: int, arg: int,
             seal_idx: int, sc_start: int, sc_count: int,
             ret: int = 0) -> None:
        """Publish a request: one record store (state=R_REQ included).
        ``ret`` is dead weight until completion, so a posted deadline
        (F_DEADLINE) travels there — zero extra layout, zero extra
        stores."""
        self.arr[slot] = (seq, fn, flags, arg, seal_idx,
                          ret, R_REQ, OK, sc_start, sc_count)

    def load(self, slot: int) -> Tuple:
        """Full-slot load as a tuple of Python scalars."""
        return self.arr[slot].item()

    def load_req(self, slot: int) -> Tuple[int, int, int, int, int, int]:
        """Request-half load: (fn, flags, arg, seal_idx, sc_start, sc_count)
        — the fields the receiver dispatches on, as five word loads."""
        words = self._words
        w = self._w0 + slot * _SLOT_WORDS
        ff = words[w + 1]
        sc = words[w + 6]
        return (ff & _M32, ff >> 32, words[w + 2], words[w + 3],
                sc & _M32, sc >> 32)

    def state_of(self, slot: int) -> int:
        """u32 slot state (one word load; status shares the word)."""
        return self._words[self._w0 + slot * _SLOT_WORDS + _W_STATE] & _M32

    def complete(self, slot: int, ret: int, state: int, status: int) -> None:
        """Receiver half: ret, then state+status in one publishing store."""
        w = self._w0 + slot * _SLOT_WORDS
        self._words[w + _W_RET] = ret
        self._words[w + _W_STATE] = (status << 32) | state

    def consume(self, slot: int) -> Tuple[int, int, int]:
        """Sender half: read (ret, state, status) and free the slot."""
        w = self._w0 + slot * _SLOT_WORDS
        ret = self._words[w + _W_RET]
        ss = self._words[w + _W_STATE]
        self._words[w + _W_STATE] = R_EMPTY  # status resets to OK too
        return ret, ss & _M32, ss >> 32


class RpcError(ChannelError):
    def __init__(self, status: int):
        super().__init__(f"RPC failed with status {status}")
        self.status = status


class _Pending:
    """Client-side record of one tracked async token (``invoke_async``
    futures; raw ``call_async`` tokens stay registry-free so the no-op
    hot path pays nothing). Exists so ``close()`` can drain a pending
    future's scopes exactly once and the reaper can recycle the reply
    of a cancelled/abandoned token when its completion lands."""

    __slots__ = ("sealed", "seal_idx", "typed", "cleanup")

    def __init__(self, sealed: bool = False, seal_idx: int = 0,
                 typed: bool = False,
                 cleanup: Optional[Callable[[], None]] = None):
        self.sealed = sealed
        self.seal_idx = seal_idx
        self.typed = typed
        self.cleanup = cleanup


def _admission_park(conn, ring, slot: int, deadline_us: int,
                    reap: Optional[Callable[[], None]] = None) -> None:
    """Bounded backpressure (§5.4): park the caller of a full ring in a
    bounded admission queue until its slot frees, instead of failing the
    post outright.

    The wait budget derives from the descriptor deadline when the call
    posted one (past that instant the request could not complete in time
    anyway), capped by the connection's ``admission_wait_s``; the poll
    cadence reuses the §5.8 ``BusyWaitPolicy`` after a GIL-yield spin
    budget. Three exits: the slot frees (return, the post proceeds), the
    queue is already at ``admission_max_waiters`` or the budget lapses
    (``Overloaded`` with a suggested retry-after), or the connection is
    closed under the waiter (``ChannelError``). All raising exits happen
    before the seq is claimed — a turned-away post burns no seq.
    """
    if conn._admission_waiters >= conn.admission_max_waiters:
        conn.n_overloads += 1
        raise Overloaded(
            "ring overflow: admission queue full "
            f"({conn.admission_max_waiters} parked waiters)",
            retry_after_s=conn.admission_wait_s)
    budget = conn.admission_wait_s
    if deadline_us:
        budget = min(budget, deadline_us * 1e-6 - time.monotonic())
    policy = conn.wait_policy
    give_up = time.monotonic() + max(0.0, budget)
    spins = _WAIT_SPIN_POLLS
    conn._admission_waiters += 1
    conn.n_admission_waits += 1
    try:
        while ring.state_of(slot) != R_EMPTY:
            if conn.closed:
                raise ChannelError(
                    "connection closed while parked in the admission "
                    "queue")
            if reap is not None:
                reap()   # completions of abandoned tokens free slots
                if ring.state_of(slot) == R_EMPTY:
                    return
            if time.monotonic() > give_up:
                conn.n_overloads += 1
                raise Overloaded(
                    "ring overflow: admission budget lapsed with the "
                    "slot still in flight",
                    retry_after_s=conn.admission_wait_s)
            if spins:
                spins -= 1
                time.sleep(0)
            else:
                # delay_s() may prescribe a pure spin (0.0) — floor it at
                # a 5µs nap so a long park cannot hard-spin the GIL
                time.sleep(policy.delay_s() or 5e-6)
    finally:
        conn._admission_waiters -= 1


class Connection:
    """One client's connection: heap + ring + seal/sandbox managers."""

    RING_CLS = DescriptorRing

    def __init__(self, channel: "Channel", heap: SharedHeap, client_pid: int,
                 ring_capacity: int = 256):
        self.channel = channel
        self.heap = heap
        self.client_pid = client_pid
        self.ring = self.RING_CLS(heap, ring_capacity)
        self.seals = SealManager(heap)
        self.sandboxes = SandboxManager(heap)
        self._next_seq = 1
        self._scope_pool: Optional[ScopePool] = None
        self.closed = False
        self.last_seal_idx = 0  # seal idx of the most recent sealed call
        self._ctx: Optional["ServerCtx"] = ServerCtx(channel, self, 0)
        # typed data plane (core/marshal.py): pooled argument scopes,
        # server reply scopes recycled through the client, and the
        # implicit-allocation scope backing scope-less new_bytes calls.
        self._marshal_pool: Optional[ScopePool] = None
        self._reply_free: List[Scope] = []
        self._reply_live: Dict[int, Scope] = {}
        # streaming replies: recycled chunk-chain scopes + the per-call
        # generation counter that tags every chunk of a stream
        self._chain_free: List[Scope] = []
        self._stream_gen = 0
        self._implicit: Optional[Scope] = None
        self._implicit_scopes: List[Scope] = []
        # pipelined-futures bookkeeping: every async token is tracked so
        # close() fails its waiter instead of stranding it, and abandoned
        # tokens (timeout/cancel) are reaped once their reply lands
        self._pending_async: Dict[int, _Pending] = {}
        self._abandoned: Dict[int, _Pending] = {}
        # §5.8 back-off for client-side waits (shared across this
        # connection's in-flight futures — one poll duty cycle). Public:
        # assign a BusyWaitPolicy(fixed_sleep_us=...) to pin the client
        # poll cadence, exactly like passing a policy to listen().
        # Defaults come from the channel's ReproConfig; assigning the
        # attributes afterwards still overrides per connection.
        cfg = getattr(channel, "config", None) or global_config
        self.wait_policy = BusyWaitPolicy(
            fixed_sleep_us=cfg.wait_fixed_sleep_us, window=cfg.wait_window)
        # bounded admission queue for a full ring (§5.4 backpressure):
        # a post that wraps onto an in-flight slot parks up to
        # ``admission_wait_s`` (or the remaining descriptor deadline,
        # whichever is shorter) for at most ``admission_max_waiters``
        # concurrent parkers, then surfaces typed ``Overloaded``.
        self.admission_wait_s = cfg.admission_wait_s
        self.admission_max_waiters = cfg.admission_max_waiters
        self._admission_waiters = 0
        # round-trip stats
        self.n_calls = 0
        self.n_invokes = 0
        self.marshal_bytes = 0
        self.n_admission_waits = 0
        self.n_overloads = 0

    # -- client-side object construction --------------------------------
    def create_scope(self, size_bytes: int) -> Scope:
        return create_scope(self.heap, size_bytes, owner=self.client_pid)

    def scope_pool(self, scope_pages: int = 1) -> ScopePool:
        if self._scope_pool is None or \
                self._scope_pool.scope_pages != scope_pages:
            self._scope_pool = ScopePool(self.heap, scope_pages,
                                         owner=self.client_pid,
                                         seals=self.seals)
        return self._scope_pool

    def new_bytes(self, data: bytes, scope: Optional[Scope] = None) -> int:
        """``conn->new_<T>(...)`` — allocate an object in the heap/scope.

        With no explicit scope the object goes into a connection-owned
        implicit scope that is tracked and returned to the heap when the
        connection closes (historically each scope-less call leaked an
        untracked single-use scope). Consecutive scope-less allocations
        share the current implicit scope until it fills.
        """
        if scope is None:
            scope = implicit_scope(self, len(data), self.heap.page_size)
        return scope.write_bytes(data, pid=self.client_pid)

    # -- the RPC itself ---------------------------------------------------
    def call(
        self,
        fn_id: int,
        arg_addr: int = gaddr.NULL,
        scope: Optional[Scope] = None,
        sealed: bool = False,
        sandboxed: bool = False,
        batch_release: bool = False,
        timeout: float = 10.0,
        spin_sleep_us: float = 0.0,
        flags_extra: int = 0,
        deadline_us: int = 0,
    ) -> int:
        """``conn->call<T>(fn_id, arg)``. Returns the ret GlobalAddr/value.

        ``sealed``: seal the scope for the flight of the RPC (§4.5).
        ``sandboxed``: ask the server to process inside a sandbox (§4.4).
        ``batch_release``: defer the seal release to the scope-pool batch
        (§5.3) rather than releasing on return.
        ``flags_extra``: extra descriptor flag bits (the typed data plane
        sets F_TYPED/F_BYVAL here — see core/marshal.py).
        ``deadline_us``: absolute request deadline (µs, monotonic); the
        receiver drops the request with E_DEADLINE once it lapses.
        """
        slot, seal_idx = self._post(fn_id, arg_addr, scope, sealed, sandboxed,
                                    flags_extra, deadline_us)
        # spin for the response (client side of §5.8); time.sleep(0) is the
        # CPython GIL-yield stand-in for a hardware pause-loop. The poll is
        # one u64 word load (state|status) with everything hoisted.
        ring = self.ring
        words = ring._words
        widx = ring._w0 + slot * _SLOT_WORDS + _W_STATE
        sleep_s = spin_sleep_us * 1e-6 if spin_sleep_us else 0
        deadline = time.monotonic() + timeout
        dl_s = deadline_us * 1e-6 if deadline_us else 0.0
        if dl_s and dl_s < deadline:
            deadline = dl_s
        while words[widx] & _M32 < R_DONE:
            if time.monotonic() > deadline:
                if dl_s and deadline == dl_s:
                    # the REQUEST deadline lapsed, not the caller's
                    # patience: terminal, never retryable (the budget
                    # is gone — retrying would mint a fresh one)
                    raise DeadlineExceeded("RPC deadline lapsed")
                raise ChannelError(f"RPC {fn_id} timed out")
            time.sleep(sleep_s)
        return self._complete(slot, sealed, seal_idx, batch_release)

    def call_inline(self, fn_id: int, arg_addr: int = gaddr.NULL,
                    scope: Optional[Scope] = None, sealed: bool = False,
                    sandboxed: bool = False,
                    batch_release: bool = False,
                    flags_extra: int = 0,
                    deadline_us: int = 0) -> int:
        """Same data path as ``call`` but the server half runs on this
        thread immediately after the descriptor is posted — the two-core
        zero-scheduling-noise configuration used for RTT microbenchmarks
        (a dedicated server core picks the descriptor up instantly; CPython
        threads would add GIL handoff latency that the hardware does not
        have)."""
        slot, seal_idx = self._post(fn_id, arg_addr, scope, sealed, sandboxed,
                                    flags_extra, deadline_us)
        self.channel._process(self, slot)
        self.ring.head += 1
        return self._complete(slot, sealed, seal_idx, batch_release)

    def call_async(self, fn_id: int, arg_addr: int = gaddr.NULL,
                   scope: Optional[Scope] = None, sealed: bool = False,
                   sandboxed: bool = False,
                   flags_extra: int = 0,
                   deadline_us: int = 0) -> Tuple[int, int]:
        """Post without waiting; returns a (slot, seal_idx) token. Multiple
        RPCs may be in flight on one connection (per-thread MPK permissions
        make this safe in the paper, §5.2). Closing the connection fails
        every outstanding ``wait`` with ``ChannelError`` instead of
        leaving it to spin on a destroyed ring."""
        return self._post(fn_id, arg_addr, scope, sealed, sandboxed,
                          flags_extra, deadline_us)

    def _track_async(self, token: Tuple[int, int], sealed: bool = False,
                     typed: bool = False,
                     cleanup: Optional[Callable[[], None]] = None
                     ) -> "_Pending":
        """Register close()/reap bookkeeping for an async token (the
        futures layer calls this; raw tokens stay registry-free)."""
        p = _Pending(sealed, token[1], typed, cleanup)
        self._pending_async[token[0]] = p
        return p

    # -- typed data plane (core/marshal.py) -------------------------------
    def invoke(self, fn_id: int, *args, **kw):
        """``conn->invoke(fn_id, *values)`` — typed zero-copy RPC.

        Arguments (arbitrary nested Python values, or pre-built
        ``GraphRef`` container graphs) are materialized ONCE as a
        ``containers`` graph in a pooled scope and passed as a single
        GlobalAddr — no serialization. The reply is marshalled back the
        same way. Handlers must be registered with ``Channel.add_typed``.
        Keywords: ``sealed``, ``sandboxed``, ``batch_release``,
        ``timeout``, ``inline`` (use the two-core inline data path).
        """
        return _get_marshal().invoke_cxl(self, fn_id, args, **kw)

    def invoke_async(self, fn_id: int, *args, **kw):
        """Pipelined typed invoke: post now, settle later. Returns an
        ``RpcFuture``; N futures may be in flight on one connection and
        complete out of order (``marshal.gather`` drains them as they
        land). Keywords: ``sealed``, ``sandboxed``, ``deadline``
        (seconds of budget, propagated into the descriptor), ``timeout``."""
        return _get_marshal().invoke_async_cxl(self, fn_id, args, **kw)

    def invoke_stream(self, fn_id: int, *args, **kw):
        """Streaming typed invoke: the handler is a generator and every
        yielded value arrives as one generation-tagged chunk on a reply
        chain the server grows while the call is still in flight. Returns
        an ``RpcStream`` iterator — chunks are consumed **as they land**
        (time-to-first-token, not time-to-last). Keywords: ``sealed``,
        ``sandboxed``, ``deadline``, ``timeout``, ``window`` (bounded
        chunk window — server-side backpressure), ``inline`` (pump the
        server stream from the consuming thread; the two-core analogue
        for single-threaded setups)."""
        return _get_marshal().invoke_stream_cxl(self, fn_id, args, **kw)

    def invoke_serialized(self, fn_id: int, *args, **kw):
        """The Fig. 11 serializing baseline over the SAME descriptor ring:
        args are ``serial.encode``d, copied into a scope, decoded by the
        receiver — everything the typed pointer path avoids."""
        return _get_marshal().invoke_serialized(self, fn_id, args, **kw)

    def serve(self, instance, interceptors=()):
        """Register every method of a ``@service``-decorated instance as
        a typed handler on this connection's channel (see
        core/service.py). The raw integer ``add``/``add_typed`` API stays
        as the low-level escape hatch."""
        return self.channel.serve(instance, interceptors)

    def poll(self, token: Tuple[int, int]) -> bool:
        """Non-blocking completion probe for an async token (one word
        load). True once the result may be consumed with ``wait``."""
        ring = self.ring
        return ring._words[ring._w0 + token[0] * _SLOT_WORDS + _W_STATE] \
            & _M32 >= R_DONE

    def wait(self, token: Tuple[int, int], sealed: bool = False,
             batch_release: bool = False, timeout: float = 10.0) -> int:
        if self.closed:
            raise ChannelError("wait on closed connection")
        slot, seal_idx = token
        ring = self.ring
        words = ring._words
        widx = ring._w0 + slot * _SLOT_WORDS + _W_STATE
        if words[widx] & _M32 < R_DONE:  # not already done: back-off spin
            # §5.8 on the client side, through the same BusyWaitPolicy
            # the serve loops use: a bounded GIL-yield spin absorbs
            # promptly-landing replies (the pipelined steady state pays
            # nothing beyond the old hard spin), then the policy back-off
            # takes over so a stalled wait stops burning a core. The
            # policy's duty sample is one bit per wait — did this wait
            # overrun its spin budget? — so sustained stalls escalate to
            # the 5µs/150µs naps while a healthy pipeline keeps spinning.
            # A fixed-cadence policy (wait_policy with fixed_sleep_us)
            # skips the spin budget: the caller pinned the poll interval.
            policy = self.wait_policy
            deadline = time.monotonic() + timeout
            spins = _WAIT_SPIN_POLLS if policy.fixed is None else 0
            overran = spins == 0
            while words[widx] & _M32 < R_DONE:
                if time.monotonic() > deadline:
                    raise WaitTimeout("RPC timed out")
                if self.closed:
                    raise ChannelError("connection closed while waiting")
                if spins:
                    spins -= 1
                    time.sleep(0)
                    continue
                if not overran:
                    overran = True
                    policy.record(True)
                time.sleep(policy.delay_s())
            if not overran:
                policy.record(False)
        if self._pending_async:
            self._pending_async.pop(slot, None)
        return self._complete(slot, sealed, seal_idx, batch_release)

    def end_seal_window(self) -> int:
        """Close a ``batch_release`` pipeline window: flush every queued
        seal release in ONE permission epoch (§5.3 composed with
        pipelining). Returns the number of releases applied."""
        n = self.seals.pending_releases()
        if n:
            self.seals.flush()
        return n

    # -- abandoned-token reaping (timeout / cancel hygiene) ----------------
    def _abandon(self, token: Tuple[int, int], pending: "_Pending") -> None:
        """Give up on an async token (future cancelled or its waiter timed
        out for good): the slot cannot be un-posted from an SPSC ring, so
        it is parked and reaped — consumed, reply scope recycled, seal
        released — as soon as the server's completion lands."""
        slot = token[0]
        self._pending_async.pop(slot, None)
        self._abandoned[slot] = pending
        self._reap_abandoned()

    def _reap_abandoned(self) -> None:
        if not self._abandoned:
            return
        ring = self.ring
        for slot in list(self._abandoned):
            if ring.state_of(slot) < R_DONE:
                continue   # still in flight; reap on a later pass
            p = self._abandoned.pop(slot)
            tr = self.heap._tracer
            if tr is not None:
                tr.sync_acquire(("rep", id(ring), slot))
            ret, state, _status = ring.consume(slot)
            if p.sealed:
                try:
                    self.seals.release(p.seal_idx, holder=self.client_pid)
                except SealViolation:
                    pass
            if p.typed and state == R_DONE:
                _get_marshal()._recycle_reply(self, ret)
            if p.cleanup is not None:
                p.cleanup()
                p.cleanup = None

    # -- data-path halves ---------------------------------------------------
    def _post(self, fn_id, arg_addr, scope, sealed, sandboxed,
              flags_extra=0, deadline_us=0):
        if self.closed:
            raise ChannelError("call on closed connection")
        if self._abandoned:
            self._reap_abandoned()   # free slots stranded by cancel/timeout
        if deadline_us:
            flags_extra |= F_DEADLINE
        ring = self.ring
        seq = self._next_seq
        slot = seq % ring.capacity
        # a slot is free only once its result was consumed: R_REQ means the
        # window wrapped onto a pending request, R_DONE/R_ERR onto a result
        # nobody waited on — overwriting either would alias two calls.
        # A full ring no longer fails instantly: the caller parks in the
        # bounded admission queue (§5.4) and only a full queue or a
        # lapsed budget surfaces Overloaded.
        if ring._words[ring._w0 + slot * _SLOT_WORDS + _W_STATE] & _M32 \
                != R_EMPTY:
            _admission_park(self, ring, slot, deadline_us,
                            reap=self._reap_abandoned)

        # The seq is claimed only after every raising path (overflow,
        # missing scope, seal failure): a rejected post must not burn a
        # seq, or the server head would wait forever on a request that
        # was never written.
        if scope is None:  # plain-call fast path: no pages, no seal
            if sealed:
                raise SealViolation("sealed call requires a scope (§4.5)")
            self._next_seq = seq + 1
            tr = self.heap._tracer
            if tr is not None:  # ShmCheck: post publishes the args
                tr.sync_release(("req", id(ring), slot))
            ring.arr[slot] = (seq, fn_id,
                              (F_SANDBOXED if sandboxed else 0) | flags_extra,
                              arg_addr, 0, deadline_us, R_REQ, OK, 0, 0)
            ch = self.channel
            if ch._parked:  # doorbell only when the server is waiting on it
                ch._event.set()
            return slot, 0

        flags = flags_extra
        seal_idx = 0
        sc_start, sc_count = scope.page_range()
        if sealed:
            seal_idx = self.seals.seal(scope, holder=self.client_pid)
            self.last_seal_idx = seal_idx
            flags |= F_SEALED
        if sandboxed:
            flags |= F_SANDBOXED

        self._next_seq = seq + 1
        tr = self.heap._tracer
        if tr is not None:  # ShmCheck: post publishes the scope's bytes
            tr.sync_release(("req", id(ring), slot))
        ring.post(slot, seq, fn_id, flags, arg_addr, seal_idx,
                  sc_start, sc_count, ret=deadline_us)
        ch = self.channel
        if ch._parked:
            ch._event.set()
        return slot, seal_idx

    def _complete(self, slot, sealed, seal_idx, batch_release):
        tr = self.heap._tracer
        if tr is not None:  # ShmCheck: consume observes the reply bytes
            tr.sync_acquire(("rep", id(self.ring), slot))
        ret, state, status = self.ring.consume(slot)
        self.n_calls += 1

        if sealed:
            if batch_release:
                self.seals.release_batched(seal_idx, holder=self.client_pid)
            else:
                self.seals.release(seal_idx, holder=self.client_pid)

        if state == R_ERR:
            if status == E_DEADLINE:
                raise DeadlineExceeded("RPC deadline lapsed")
            if status == E_OVERLOAD:
                # the shed reply's ret word is the server-suggested
                # retry-after in µs (§5.4)
                raise Overloaded("server shed the request (E_OVERLOAD)",
                                 retry_after_s=ret * 1e-6)
            raise RpcError(status)
        return ret

    # -- lifecycle ----------------------------------------------------------
    def close(self) -> None:
        if not self.closed:
            self.closed = True
            # drain every tracked in-flight future FIRST: ``closed`` makes
            # a later wait()/result() raise ChannelError instead of
            # spinning on a torn-down ring, and each token's marshal
            # scope is drained exactly once (the cleanup callback is
            # one-shot) before the pools it belongs to are destroyed
            # below.
            for p in (*self._pending_async.values(),
                      *self._abandoned.values()):
                if p.cleanup is not None:
                    p.cleanup()
                    p.cleanup = None
            self._abandoned.clear()
            # return every connection-owned page range to the heap: the
            # implicit new_bytes scopes, the marshal scope pool, and any
            # reply scopes the server handed back through this client.
            for s in self._implicit_scopes:
                if s.live:
                    s.destroy()
            self._implicit_scopes.clear()
            self._implicit = None
            if self._marshal_pool is not None:
                self._marshal_pool.drain()
                self._marshal_pool = None
            for s in (*self._reply_free, *self._chain_free):
                if s.live:
                    s.destroy()
            self._reply_free.clear()
            self._chain_free.clear()
            for s in self._reply_live.values():
                if s.live:
                    s.destroy()
            self._reply_live.clear()
            # the user-facing scope_pool() pool is connection-owned too:
            # its pre-created pages historically outlived the connection
            # (found by the ShmCheck leak-at-close checker)
            if self._scope_pool is not None:
                self._scope_pool.drain()
                self._scope_pool = None
            tr = self.heap._tracer
            if tr is not None:
                tr.on_conn_close(self.heap, self.client_pid, self.seals)
            self.channel._drop_connection(self)


class Channel:
    """A named RPC endpoint. ``Channel.open`` ≈ binding a port (§4.2)."""

    CONN_CLS = Connection

    def __init__(self, orch: Orchestrator, name: str, server_pid: int,
                 heap_pages: int = 4096, page_size: int = 4096,
                 shared_heap: bool = False,
                 config: Optional[ReproConfig] = None):
        self.orch = orch
        self.name = name
        # tuning defaults for this channel and its connections; explicit
        # kwargs / attribute assignment still override per instance
        self.config = config or global_config
        self.server_pid = server_pid
        self.heap_pages = heap_pages
        self.page_size = page_size
        self.shared_heap = shared_heap  # Fig. 4b channel-wide heap
        self._shared: Optional[SharedHeap] = None
        self.functions: Dict[int, Callable[["ServerCtx", int], int]] = {}
        self.connections: List[Connection] = []
        self._event = threading.Event()
        self._parked = False  # True only while listen waits on the doorbell
        self._stop = threading.Event()
        self._sweep_scratch: Optional[np.ndarray] = None
        self._conn_version = 0  # bumped on accept/drop; ServerLoop caches
        # active streaming replies (ServerStream, core/marshal.py): the
        # serve loops advance every registered generator a bounded number
        # of chunks per sweep, so streams interleave with ordinary RPCs
        self._streams: List = []
        # pre-dispatch admission gate (§5.4): an AdmissionInterceptor
        # (core/service.py) wired here sheds requests with E_OVERLOAD —
        # one descriptor word, never a handler. Anything exposing
        # admit(client_pid, fn_id) -> Optional[retry_after_us] / release()
        # plugs in.
        self.admission = None
        # push-mode per-pump chunk cap applied to every stream this
        # channel registers (None = the client's full window). A serving
        # transport whose stream generators share one scheduler (e.g.
        # continuous batching) sets 1 so all live streams advance in
        # lockstep, one batched step per sweep.
        self.stream_pump_burst: Optional[int] = self.config.stream_pump_burst
        # the served instance (recorded by serve()) — what snapshot()
        # checkpoints and the lifecycle Endpoint handle manages
        self.served_instance = None
        self.served_def = None
        self.serve_interceptors: Tuple = ()
        self.lifecycle = None  # back-ref set by lifecycle.Endpoint
        orch.register_channel(name, self)

    # -- server API (Fig. 6 left) -------------------------------------------
    def add(self, fn_id: int, fn: Callable[["ServerCtx", int], int]) -> None:
        self.functions[fn_id] = fn

    def add_typed(self, fn_id: int, fn) -> None:
        """Register a typed handler: ``fn(ctx, args)`` receives an
        ``ArgView`` (lazy, bounds-checked when sandboxed) over the
        marshalled argument tuple and returns a Python value, which is
        marshalled back to the caller. Serves both the pointer-passing
        (``invoke``) and the serialized (``invoke_serialized`` /
        fallback-route) forms of the request."""
        self.functions[fn_id] = _get_marshal().typed_handler(fn)

    def serve(self, instance, interceptors=()):
        """Register every method of a ``@service``-decorated instance
        (or anything carrying a ``ServiceDef``) as a typed handler —
        the declarative face of ``add_typed`` (core/service.py). Returns
        the ``ServiceDef``. The raw integer ``fn_id`` API above remains
        the documented low-level escape hatch."""
        from .service import service_def
        sdef = service_def(instance)
        sdef.serve(self, instance, interceptors)
        self.served_instance = instance
        self.served_def = sdef
        self.serve_interceptors = tuple(interceptors)
        return sdef

    def accept(self, client_pid: int, ring_capacity: int = 256) -> Connection:
        """Create the connection object for a connecting client."""
        if self.shared_heap:
            if self._shared is None:
                self._shared = self.orch.create_heap(
                    self.heap_pages, self.page_size,
                    name=f"{self.name}/shared")
                self.orch.map_heap(self.server_pid, self._shared)
            heap = self._shared
        else:
            heap = self.orch.create_heap(
                self.heap_pages, self.page_size,
                name=f"{self.name}/conn{len(self.connections)}")
            self.orch.map_heap(self.server_pid, heap)
        self.orch.map_heap(client_pid, heap)
        conn = self.CONN_CLS(self, heap, client_pid, ring_capacity)
        self.connections.append(conn)
        self._conn_version += 1
        return conn

    def _drop_connection(self, conn: Connection) -> None:
        if conn in self.connections:
            self.connections.remove(conn)
            self._conn_version += 1
            if self._streams:
                # a dropped client's streams must never pump again (their
                # chain pages are going back to the heap)
                for st in [s for s in self._streams if s.conn is conn]:
                    st.abort()
                    self._streams.remove(st)
            self.orch.unmap_heap(conn.client_pid, conn.heap.heap_id)
            if not self.shared_heap:
                self.orch.unmap_heap(self.server_pid, conn.heap.heap_id)

    # Doorbell contract (no helper — Connection._post inlines it): a post
    # rings self._event only when self._parked is set, i.e. while listen()
    # is blocked on the event; posts during a sweep are found by the next
    # sweep.

    # -- serve loop ------------------------------------------------------------
    def serve_once(self) -> int:
        """One vectorized sweep: gather every connection ring's head-slot
        state, find ready rings with a single NumPy compare, and drain each
        ready ring inline. Rings are SPSC and clients claim slots in seq
        order, so only each ring's head needs inspecting. Returns the
        number of RPCs served."""
        conns = self.connections
        n = len(conns)
        if n == 0:
            return self.pump_streams()
        if n == 1:  # common case: skip the gather entirely
            return self._drain(conns[0]) + self.pump_streams()
        conns = list(conns)  # handlers may drop connections mid-drain
        scratch = self._sweep_scratch
        if scratch is None or scratch.shape[0] < n:
            self._sweep_scratch = scratch = np.empty(max(8, 2 * n),
                                                     dtype=np.uint32)
        for i, conn in enumerate(conns):
            ring = conn.ring
            scratch[i] = ring.state_of(ring.head % ring.capacity)
        ready = np.flatnonzero(scratch[:n] == R_REQ)  # ONE compare
        served = 0
        for i in ready:
            served += self._drain(conns[i])
        return served + self.pump_streams()

    def pump_streams(self) -> int:
        """Advance every active streaming reply: each registered generator
        emits chunks up to its client's open window (bounded — a stalled
        consumer cannot pin the sweep), streams that finish are dropped.
        Returns the number of chunks emitted, which counts as served work
        for the §5.8 policy so a mid-stream server never backs off."""
        if not self._streams:
            return 0
        emitted = 0
        for st in list(self._streams):
            emitted += st.pump()
            if st.done:
                self._streams.remove(st)
        return emitted

    def _drain(self, conn: Connection) -> int:
        """Process every pending slot of one ring (batched head advance).
        The readiness poll is a single hoisted u64 word load per slot."""
        ring = conn.ring
        cap = ring.capacity
        words = ring._words
        w0 = ring._w0 + _W_STATE
        head = ring.head
        served = 0
        while True:
            slot = head % cap
            if words[w0 + slot * _SLOT_WORDS] & _M32 != R_REQ:
                break
            self._process(conn, slot)
            head += 1
            served += 1
        ring.head = head
        return served

    def serve_many(self, max_sweeps: Optional[int] = None) -> int:
        """Drain every ready slot found, sweep after sweep, until the
        channel is idle (or ``max_sweeps`` sweeps have run). Requests that
        arrive while a batch is being drained are picked up by the next
        sweep without returning to the caller."""
        total = 0
        sweeps = 0
        while True:
            n = self.serve_once()
            total += n
            sweeps += 1
            if n == 0 or (max_sweeps is not None and sweeps >= max_sweeps):
                return total

    def listen(self, policy: Optional[BusyWaitPolicy] = None,
               stop: Optional[threading.Event] = None) -> None:
        """``conn->listen()`` — busy-wait loop with §5.8 adaptive back-off
        spent parked on the doorbell (see ``_serve_event_loop``)."""
        _serve_event_loop(self.serve_many, self.serve_once, (self,),
                          policy or BusyWaitPolicy(), stop or self._stop,
                          self._event)

    def listen_in_thread(self, policy: Optional[BusyWaitPolicy] = None
                         ) -> threading.Thread:
        self._stop.clear()
        t = threading.Thread(target=self.listen, args=(policy,), daemon=True)
        t.start()
        return t

    @classmethod
    def serve_all(cls, channels: List["Channel"],
                  policy: Optional[BusyWaitPolicy] = None) -> "ServerLoop":
        """Serve every ring of every channel in ``channels`` from ONE
        background thread (a started ``ServerLoop``). The cluster-scale
        replacement for one ``listen_in_thread`` per channel."""
        loop = ServerLoop(channels, policy)
        loop.run_in_thread()
        return loop

    def stop(self) -> None:
        self._stop.set()

    def destroy(self) -> None:
        self.stop()
        for st in self._streams:
            st.abort()   # close the generators; chain pages die with heap
        self._streams.clear()
        for conn in list(self.connections):
            conn.close()
        self.orch.unregister_channel(self.name)

    # -- request processing (receiver half of Fig. 8) ---------------------------
    def _process(self, conn: Connection, slot: int) -> None:
        ring = conn.ring
        fn_id, flags, arg, seal_idx, sc_start, sc_count = ring.load_req(slot)
        tr = conn.heap._tracer
        if tr is not None:  # ShmCheck: the load observes the posted args
            tr.sync_acquire(("req", id(ring), slot))

        fn = self.functions.get(fn_id)
        if fn is None:
            ring.complete(slot, 0, R_ERR, E_NOFUNC)
            return

        # Deadline gate (pipelined futures): a request whose propagated
        # deadline lapsed while queued is dropped before its seal/args
        # are even touched — the client already gave up on it.
        deadline_us = 0
        if flags & F_DEADLINE:
            deadline_us = int(
                ring._words[ring._w0 + slot * _SLOT_WORDS + _W_RET])
            if _now_us() > deadline_us:
                ring.complete(slot, 0, R_ERR, E_DEADLINE)
                return

        # Fig. 8 step 4: verify the seal before touching the arguments.
        if flags & F_SEALED:
            if not conn.seals.is_sealed(seal_idx):
                ring.complete(slot, 0, R_ERR, E_UNSEALED)
                return

        # Admission gate (§5.4): shed BEFORE dispatch — the reply is one
        # descriptor word (the suggested retry-after, µs) and the handler
        # never runs. Sits after the early-return gates above so an
        # admitted slot always reaches the release below (or hands its
        # release to the stream it started).
        gate = self.admission
        if gate is not None:
            retry_after_us = gate.admit(conn.client_pid, fn_id)
            if retry_after_us is not None:
                ring.complete(slot, retry_after_us, R_ERR, E_OVERLOAD)
                return

        # Reuse the connection's ServerCtx (allocation-free steady state);
        # a nested call_inline from inside a handler sees None and gets a
        # fresh one.
        ctx = conn._ctx
        if ctx is None:
            ctx = ServerCtx(self, conn, flags)
        else:
            conn._ctx = None
            ctx.flags = flags
            ctx.sandbox = None
        ctx.deadline_us = deadline_us
        try:
            if flags & F_SANDBOXED and not gaddr.is_null(arg):
                if sc_count:
                    start, count = sc_start, sc_count
                else:
                    # no scope advertised: sandbox the argument's extent
                    start, count = self._arg_scope(conn, arg)
                with conn.sandboxes.enter(start, count) as sb:
                    ctx.sandbox = sb
                    ret = fn(ctx, arg)
            else:
                ret = fn(ctx, arg)
            if getattr(ret, "_server_stream", False):
                # streaming reply: the slot stays open (and its seal
                # held) until the chunk chain ends; the serve loops pump
                # the generator from here on. The ctx travels with the
                # stream, so it is NOT returned to the connection.
                ret.bind(conn, ring, slot, seal_idx, flags,
                         sc_start, sc_count)
                ret.burst = self.stream_pump_burst
                if gate is not None:
                    # the stream stays admitted until its chain ends:
                    # abort()/completion fires the release exactly once
                    ret.release_cb = gate.release
                    gate = None
                self._streams.append(ret)
                ret.pump()   # first chunks flow before the sweep returns
                if ret.done:
                    self._streams.remove(ret)
                return
            status, state = OK, R_DONE
        except SandboxViolation:
            # the SIGSEGV→error-reply path (§4.4)
            ret, status, state = 0, E_SANDBOX, R_ERR
        except DeadlineExceeded:
            # a handler/interceptor aborting past the budget keeps the
            # dedicated status so clients see a deadline, not a crash
            ret, status, state = 0, E_DEADLINE, R_ERR
        except Overloaded as e:
            # a handler shedding on resource pressure (e.g. pool pages,
            # §5.4) rides the same typed E_OVERLOAD reply as the
            # pre-dispatch gate: the ret word carries retry-after µs
            ret = max(0, int(e.retry_after_s * 1e6))
            status, state = E_OVERLOAD, R_ERR
        except Exception:
            ret, status, state = 0, E_EXCEPTION, R_ERR

        # Fig. 8 step 6: mark complete before replying.
        if flags & F_SEALED:
            try:
                conn.seals.mark_complete(seal_idx)
            except SealViolation:
                pass
        if tr is not None:  # ShmCheck: completion publishes the reply
            tr.sync_release(("rep", id(ring), slot))
        ring.complete(slot, ret, state, status)
        if gate is not None:
            gate.release()
        conn._ctx = ctx

    @staticmethod
    def _arg_scope(conn: Connection, arg: int,
                   max_pages: int = 64) -> Tuple[int, int]:
        """Best-effort scope bounds for an argument address: the contiguous
        USED extent around its page (scopes are contiguous allocations),
        bounded to ``max_pages`` each way."""
        page = gaddr.page_of(arg)
        heap = conn.heap
        lo = page
        while lo > 0 and page - lo < max_pages and \
                heap.state[lo - 1] == 1 and \
                heap.owner[lo - 1] == heap.owner[page]:
            lo -= 1
        hi = page + 1
        while hi < heap.num_pages and hi - page < max_pages and \
                heap.state[hi] == 1 and \
                heap.owner[hi] == heap.owner[page]:
            hi += 1
        return lo, hi - lo


class ServerLoop:
    """One server thread serving *all* rings of N channels (§4.6 scale-out).

    Extends ``Channel.serve_once``'s per-channel sweep **across channels**:
    each iteration gathers the head-slot state of every accepted ring of
    every attached channel into one scratch array and finds the ready rings
    with a single vectorized NumPy compare. The §5.8 busy-wait budget and
    the doorbell are likewise shared: attaching a channel rebinds its
    doorbell event to the loop's, so while the loop is parked a post on
    ANY attached channel wakes it immediately.

    The flat connection list is cached and invalidated by the channels'
    ``_conn_version`` counters, so the steady state does no list rebuilds —
    the sweep is one Python loop of word loads plus ONE compare, exactly
    like PR 1's single-channel sweep, just wider.
    """

    def __init__(self, channels: Optional[List[Channel]] = None,
                 policy: Optional[BusyWaitPolicy] = None):
        self.channels: List[Channel] = []
        self.policy = policy or BusyWaitPolicy()
        self._event = threading.Event()   # the one shared doorbell
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self._conns: List[Connection] = []
        self._versions: List[int] = []
        self._scratch: Optional[np.ndarray] = None
        # stats
        self.n_sweeps = 0
        self.n_served = 0
        for ch in (channels or []):
            self.attach(ch)

    # -- channel set --------------------------------------------------------
    def attach(self, channel: Channel) -> None:
        if channel not in self.channels:
            self.channels.append(channel)
            channel._event = self._event  # posts now ring the shared bell
            self._versions = []           # force a conn-list rebuild

    def detach(self, channel: Channel) -> None:
        if channel in self.channels:
            self.channels.remove(channel)
            channel._event = threading.Event()
            channel._parked = False
            self._versions = []

    def _refresh_conns(self) -> None:
        chs = self.channels
        if len(self._versions) == len(chs) and all(
                v == ch._conn_version
                for v, ch in zip(self._versions, chs)):
            return
        # snapshot versions BEFORE reading the connection lists: an accept
        # racing this rebuild then at worst forces one extra rebuild next
        # sweep, instead of being cached out (and never served) forever
        self._versions = [ch._conn_version for ch in chs]
        self._conns = [c for ch in chs for c in ch.connections]
        n = len(self._conns)
        if n > 1 and (self._scratch is None or self._scratch.shape[0] < n):
            self._scratch = np.empty(max(8, 2 * n), dtype=np.uint32)

    # -- sweeps -------------------------------------------------------------
    def sweep_once(self) -> int:
        """One vectorized sweep over every ring of every channel; drains
        each ready ring inline. Returns the number of RPCs served."""
        self._refresh_conns()
        conns = self._conns
        n = len(conns)
        self.n_sweeps += 1
        if n == 0:
            return 0
        if n == 1:  # common case: skip the gather
            conn = conns[0]
            served = conn.channel._drain(conn)
        else:
            scratch = self._scratch
            for i, conn in enumerate(conns):
                ring = conn.ring
                scratch[i] = ring.state_of(ring.head % ring.capacity)
            ready = np.flatnonzero(scratch[:n] == R_REQ)  # ONE compare
            served = 0
            for i in ready:
                conn = conns[i]
                served += conn.channel._drain(conn)
        for ch in self.channels:
            if ch._streams:
                served += ch.pump_streams()
        self.n_served += served
        return served

    def serve_pending(self, max_sweeps: Optional[int] = None) -> int:
        """Sweep until idle (cf. ``Channel.serve_many``, across channels)."""
        total = 0
        sweeps = 0
        while True:
            n = self.sweep_once()
            total += n
            sweeps += 1
            if n == 0 or (max_sweeps is not None and sweeps >= max_sweeps):
                return total

    # -- the event loop ------------------------------------------------------
    def run(self, stop: Optional[threading.Event] = None) -> None:
        """Busy-wait loop with the §5.8 back-off spent parked on the shared
        doorbell (same protocol as ``Channel.listen``, across channels)."""
        _serve_event_loop(self.serve_pending, self.sweep_once,
                          self.channels, self.policy, stop or self._stop,
                          self._event)

    def run_in_thread(self) -> threading.Thread:
        self._stop.clear()
        t = threading.Thread(target=self.run, daemon=True,
                             name="rpcool-serverloop")
        self._thread = t
        t.start()
        return t

    def stop(self, join: bool = True, timeout: float = 2.0) -> None:
        """Stop the loop; by default join the serving thread (clean
        shutdown — no leaked listener threads)."""
        self._stop.set()
        self._event.set()  # wake a parked loop immediately
        t = self._thread
        if join and t is not None and t is not threading.current_thread():
            t.join(timeout)
        self._thread = None

    @property
    def running(self) -> bool:
        return self._thread is not None and self._thread.is_alive()


class ServerCtx:
    """What an RPC handler sees: checked access to the connection heap."""

    __slots__ = ("channel", "conn", "flags", "sandbox", "deadline_us")

    def __init__(self, channel: Channel, conn: Connection, flags: int):
        self.channel = channel
        self.conn = conn
        self.flags = flags
        self.sandbox = None  # set when sandboxed
        self.deadline_us = 0  # propagated request deadline (0 = none)

    def read(self, a: int, nbytes: int):
        if self.sandbox is not None:
            return self.sandbox.read(a, nbytes)
        heap = self.conn.heap
        if heap._tracer is not None:
            # ShmCheck: an invalid pointer reaching an UNsandboxed
            # handler is the §4.4 wild-dereference bug class
            return heap._tracer.checked_deref(heap, a, nbytes)
        return heap.read(a, nbytes)

    def write(self, a: int, data) -> None:
        """Handler-facing store: sandbox-confined exactly like ``read``
        — a sandboxed handler must not write outside its pages (§4.4)."""
        if self.sandbox is not None:
            self.sandbox.check(a, SharedHeap._payload_nbytes(data))
        self.conn.heap.write(a, data)

    def _daemon_write(self, a: int, data) -> None:
        """Privileged runtime store (reply marshalling): librpcool writes
        the reply outside the handler's sandbox, after SB_END semantics."""
        self.conn.heap.write(a, data)

    def heap(self) -> SharedHeap:
        return self.conn.heap


class RPC:
    """Top-level API mirroring Fig. 6."""

    def __init__(self, orch: Orchestrator, pid: int):
        self.orch = orch
        self.pid = pid
        self._channel: Optional[Channel] = None

    # server: rpc.open("mychannel"); rpc.add(100, fn); rpc.accept(); listen()
    def open(self, name: str, **kw) -> Channel:
        self._channel = Channel(self.orch, name, self.pid, **kw)
        return self._channel

    # client: rpc.connect("mychannel")
    def connect(self, name: str, **kw) -> Connection:
        ch = self.orch.lookup_channel(name)
        return ch.accept(self.pid, **kw)
