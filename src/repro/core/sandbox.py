"""Sandboxes — MPK-analogue pointer confinement (§4.4, §5.2).

When the receiver processes a sandboxed RPC it must be able to chase native
pointers through shared memory without a wild/invalid pointer reaching its
private memory. Intel MPK gives the paper ~tens-of-ns permission switches
via the PKRU register, with the expensive part being *key assignment* to
pages (mprotect-class cost). RPCool therefore keeps up to **14 cached
sandboxes** with pre-assigned keys (16 keys − 2 reserved for private memory
and unsandboxed shared regions) and recycles keys for uncached requests.

TPU translation: a "key" is a row in a per-heap page→key table, and the
PKRU word is a thread-local permission mask. Entering a *cached* sandbox
only swaps the thread mask (O(1), like a PKRU write). Entering an
*uncached* sandbox re-assigns keys to the page range, rebuilds the device
permission bitmap consumed by sandboxed Pallas kernels (paged attention
masks every block-table dereference against it) and re-initializes the
sandbox temp heap — the measured cached/uncached gap of Table 1b.

The SIGSEGV path: host-side ``check`` raises ``SandboxViolation``; device
kernels cannot trap, so they **mask** the offending access and set an
``oob_flag`` output which librpcool turns into an RPC error — the paper's
signal-to-error-reply path (§4.4).
"""

from __future__ import annotations

import threading
from typing import Dict, List, Tuple

import numpy as np

from . import addr as gaddr
from .errors import SandboxViolation
from .heap import SharedHeap, USED

NUM_KEYS = 16
KEY_PRIVATE = 0        # process private memory
KEY_SHARED = 1         # unsandboxed shared regions
FIRST_SANDBOX_KEY = 2  # keys 2..15 → 14 cached sandboxes (paper §5.2)
MAX_CACHED = NUM_KEYS - FIRST_SANDBOX_KEY


class _TempHeap:
    """Bump allocator for in-sandbox ``malloc`` redirection (§5.2).

    Lives inside the sandboxed region so the sandboxed thread can touch it;
    contents are lost on exit, matching the paper's semantics.
    """

    def __init__(self, size: int):
        self.buf = np.empty(size, dtype=np.uint8)
        self.bump = 0

    def reset(self) -> None:
        # Drop contents: data in the temp heap is lost after SB_END. The
        # pointer reset is sufficient — pages are recycled, not scrubbed,
        # exactly like a freed heap (allocations never read-before-write).
        self.bump = 0

    def alloc(self, n: int) -> memoryview:
        off = (self.bump + 7) & ~7
        if off + n > len(self.buf):
            raise SandboxViolation("sandbox temp heap exhausted")
        self.bump = off + n
        return memoryview(self.buf[off : off + n])


class Sandbox:
    """An entered sandbox: the thread's view while processing one RPC."""

    def __init__(self, mgr: "SandboxManager", key: int, start_page: int,
                 num_pages: int, temp: _TempHeap, cached_hit: bool):
        self.mgr = mgr
        self.key = key
        self.start_page = start_page
        self.num_pages = num_pages
        self.temp = temp
        self.cached_hit = cached_hit
        self._vars: Dict[str, bytes] = {}
        self._active = False

    # -- SB_BEGIN / SB_END ------------------------------------------------
    def __enter__(self) -> "Sandbox":
        self.mgr._activate(self)
        self._active = True
        return self

    def __exit__(self, *exc) -> None:
        self._active = False
        self.temp.reset()  # temp-heap data is lost (§5.2)
        self.mgr._deactivate(self)

    # -- checked access (the MMU/MPK fault path) ---------------------------
    def check(self, a: int, nbytes: int = 1) -> None:
        """Validate a pointer dereference. Raises SandboxViolation (the
        SIGSEGV analogue) if it escapes the sandbox."""
        if not self._active:
            raise SandboxViolation("access through inactive sandbox")
        if gaddr.is_null(a) or gaddr.heap_of(a) != self.mgr.heap.heap_id:
            raise SandboxViolation(
                f"wild pointer {a:#x} escapes sandboxed heap"
            )
        lin = gaddr.linear(a, self.mgr.heap.page_size)
        lo = self.start_page * self.mgr.heap.page_size
        hi = (self.start_page + self.num_pages) * self.mgr.heap.page_size
        if not (lo <= lin and lin + nbytes <= hi):
            raise SandboxViolation(
                f"pointer {a:#x} (+{nbytes}) outside sandbox pages "
                f"[{self.start_page},{self.start_page + self.num_pages})"
            )

    def read(self, a: int, nbytes: int) -> np.ndarray:
        self.check(a, nbytes)
        return self.mgr.heap.read(a, nbytes)

    def malloc(self, n: int) -> memoryview:
        """libc malloc redirection — allocates from the temp heap."""
        if not self._active:
            raise SandboxViolation("malloc outside active sandbox")
        return self.temp.alloc(n)

    # -- copied-in private variables (SB_BEGIN(region, var0, var1...)) -----
    def var(self, name: str) -> bytes:
        try:
            return self._vars[name]
        except KeyError:
            raise SandboxViolation(
                f"access to private variable {name!r} not copied into sandbox"
            )

    @property
    def page_size(self) -> int:
        return self.mgr.heap.page_size

    def device_bitmap(self):
        """(num_pages,) uint8 mask for sandboxed Pallas kernels: 1 where a
        block-table dereference is permitted."""
        return self.mgr._bitmap_for(self)


class SandboxManager:
    """Per-heap sandbox bookkeeping: key assignment + the 14-slot cache."""

    def __init__(self, heap: SharedHeap, temp_heap_bytes: int = 1 << 16):
        self.heap = heap
        self.temp_heap_bytes = temp_heap_bytes
        # cache: (start_page, num_pages) -> key
        self._cache: Dict[Tuple[int, int], int] = {}
        self._lru: List[Tuple[int, int]] = []
        self._free_keys = list(range(FIRST_SANDBOX_KEY, NUM_KEYS))
        self._active_keys: Dict[int, int] = {}  # key -> active count
        # keys whose binding was invalidated while still ACTIVE: they
        # return to the free list on their final deactivation
        self._orphaned: set = set()
        self._temps: Dict[int, _TempHeap] = {}
        self._bitmaps: Dict[int, np.ndarray] = {}  # key -> page bitmap
        self._tls = threading.local()
        self._lock = threading.RLock()
        # counters
        self.cache_hits = 0
        self.cache_misses = 0

    # -- entry points -------------------------------------------------------
    def enter(self, start_page: int, num_pages: int,
              **copy_vars: bytes) -> Sandbox:
        """SB_BEGIN(start_addr, size, var0, var1, ...) — §5.2.

        Fast path: the region already has a pre-assigned key (cached
        sandbox). Slow path: recycle a key — wait for / evict an inactive
        sandbox, reassign the key to the new page range, rebuild the bitmap
        and temp heap.
        """
        rng = (start_page, num_pages)
        with self._lock:
            key = self._cache.get(rng)
            if key is not None and not self._still_valid(rng, key):
                # the pages were freed (and possibly recycled to another
                # owner) since the key was assigned — a stale cache hit
                # here would grant the sandbox access to whoever holds
                # those pages now. Invalidate and take the miss path.
                self._invalidate(rng, key)
                key = None
            if key is not None:
                self.cache_hits += 1
                cached = True
                self._touch(rng)
            else:
                self.cache_misses += 1
                cached = False
                key = self._assign_key(rng)
        sb = Sandbox(self, key, start_page, num_pages,
                     self._temps[key], cached_hit=cached)
        for name, v in copy_vars.items():
            buf = bytes(v)
            mv = sb.temp.alloc(len(buf))
            mv[:] = buf
            sb._vars[name] = buf
        return sb

    def _assign_key(self, rng: Tuple[int, int]) -> int:
        start, count = rng
        if self._free_keys:
            key = self._free_keys.pop()
        else:
            key = self._evict_one()
        # "assigning keys to pages has similar overheads as mprotect()" —
        # key-table write + epoch bump + bitmap + temp heap rebuild.
        self.heap.key[start : start + count] = key
        self.heap._bump_epoch()
        bm = np.zeros(self.heap.num_pages, dtype=np.uint8)
        bm[start : start + count] = 1
        self._bitmaps[key] = bm
        self._temps[key] = _TempHeap(self.temp_heap_bytes)
        self._cache[rng] = key
        self._lru.append(rng)
        return key

    def _still_valid(self, rng: Tuple[int, int], key: int) -> bool:
        """A cached (range → key) binding is only honourable while every
        page is still allocated AND still carries the key — free/realloc
        or a key reassignment voids it."""
        start, count = rng
        sl = slice(start, start + count)
        return bool(np.all(self.heap.state[sl] == USED)
                    and np.all(self.heap.key[sl] == key))

    def _invalidate(self, rng: Tuple[int, int], key: int) -> None:
        start, count = rng
        self._cache.pop(rng, None)
        if rng in self._lru:
            self._lru.remove(rng)
        # scrub the key off any page in the range that still carries it
        sl = slice(start, start + count)
        keys = self.heap.key[sl]
        keys[keys == key] = KEY_SHARED
        if self._active_keys.get(key, 0) == 0:
            self._bitmaps.pop(key, None)
            self._temps.pop(key, None)
            if key not in self._free_keys:
                self._free_keys.append(key)
        else:
            # still active somewhere: reclaim on final deactivation —
            # dropping it here would lose the key forever (it is in
            # neither _cache nor _free_keys)
            self._orphaned.add(key)

    def _evict_one(self) -> int:
        for i, rng in enumerate(self._lru):
            key = self._cache[rng]
            if self._active_keys.get(key, 0) == 0:
                self._lru.pop(i)
                del self._cache[rng]
                start, count = rng
                # scrub only pages still carrying THIS key: a stale range
                # whose pages were recycled into another live sandbox
                # must not have that binding's key clobbered
                keys = self.heap.key[start : start + count]
                keys[keys == key] = KEY_SHARED
                return key
        raise SandboxViolation(
            "all 14 sandbox keys active; no key available to recycle"
        )

    def _touch(self, rng: Tuple[int, int]) -> None:
        self._lru.remove(rng)
        self._lru.append(rng)

    # -- PKRU analogue -------------------------------------------------------
    def _thread_mask(self) -> int:
        return getattr(self._tls, "mask", (1 << KEY_PRIVATE) | (1 << KEY_SHARED))

    def _activate(self, sb: Sandbox) -> None:
        # PKRU write: drop every key except the sandbox's (§5.2).
        with self._lock:
            rng = (sb.start_page, sb.num_pages)
            # a held Sandbox whose key was recycled to another region (or
            # whose pages were freed) must never re-enter: its key now
            # guards someone else's pages
            if self._cache.get(rng) != sb.key or \
                    not self._still_valid(rng, sb.key):
                if self.heap._tracer is not None:
                    self.heap._tracer.on_sandbox_stale(
                        self.heap, sb.key, sb.start_page, sb.num_pages)
                raise SandboxViolation(
                    f"stale sandbox: key {sb.key} no longer guards pages "
                    f"[{sb.start_page},{sb.start_page + sb.num_pages})"
                )
            self._active_keys[sb.key] = self._active_keys.get(sb.key, 0) + 1
        self._tls.mask = 1 << sb.key
        if self.heap._tracer is not None:
            self.heap._tracer.on_sandbox_enter(
                self.heap, sb.key, sb.start_page, sb.num_pages)

    def _deactivate(self, sb: Sandbox) -> None:
        with self._lock:
            self._active_keys[sb.key] -= 1
            if self._active_keys[sb.key] == 0 and \
                    sb.key in self._orphaned:
                self._orphaned.discard(sb.key)
                self._bitmaps.pop(sb.key, None)
                self._temps.pop(sb.key, None)
                if sb.key not in self._free_keys:
                    self._free_keys.append(sb.key)
        self._tls.mask = (1 << KEY_PRIVATE) | (1 << KEY_SHARED)
        if self.heap._tracer is not None:
            self.heap._tracer.on_sandbox_exit(self.heap, sb.key)

    def in_sandbox(self) -> bool:
        return self._thread_mask() & ~((1 << KEY_PRIVATE) | (1 << KEY_SHARED)) != 0

    def check_private_access(self) -> None:
        """Touching private memory while sandboxed → SIGSEGV analogue."""
        if self.in_sandbox():
            raise SandboxViolation("private-memory access inside sandbox")

    def _bitmap_for(self, sb: Sandbox) -> np.ndarray:
        return self._bitmaps[sb.key]

    def cached_regions(self) -> int:
        return len(self._cache)
