"""Snapshot/restore for served endpoints — warm replicas from checkpoints.

The paper's orchestrator (§5.4) owns pods, leases and failover, but a
drained pod today just lapses its lease: the service state dies with the
process. CXL heaps outlive the processes attached to them ("barely
distributed, almost persistent"), so a served channel can be
checkpointed — service state, handler registration, heap/scope/seal
metadata, stream anchors — into a portable :class:`Snapshot` and brought
back warm anywhere in the cluster:

* ``snapshot(target)`` checkpoints a served ``Channel`` (or a lifecycle
  ``Endpoint`` handle). Service state is captured via the instance's
  ``__snapshot__()`` hook when present, else by walking its attributes;
  ``GraphRef`` attributes are flattened to plain Python through the
  existing ``containers`` graph walk (``GraphRef.to_python``), and the
  whole state is TLV-encoded with ``core.serial`` — the same bytes-on-
  the-wire format the fallback transport uses, so a snapshot blob is
  portable across hosts by construction.
* ``restore(snap, pod=...)`` mints a fresh server pid + channel from the
  blob, re-registers every handler, and (optionally) registers the
  channel as a warm replica of a named router endpoint and starts a
  lifecycle ``Endpoint`` serving it.
* ``sync_state(src, dst)`` re-captures and re-applies state — the
  stop-and-copy step of live migration (``ClusterRouter.migrate``),
  run after the source quiesces so writes between the warm restore and
  the handoff are never lost.

Restore semantics: state is restored, *live wires are not*. Client
connections, in-flight futures and stream chunk-chains belong to the old
process; the router's failover contract (generation bump → re-wire /
``RoutedRpcStream``'s documented mid-stream ``ChannelError``) is how
traffic moves over.
"""

from __future__ import annotations

import importlib
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

from . import serial
from .channel import Channel
from .errors import ChannelError
from .orchestrator import Orchestrator

SNAPSHOT_VERSION = 1

# -- type-preserving state encoding ------------------------------------------
# ``core.serial`` is the wire format: dict keys coerce to str, tuples
# land as lists, bools as ints. Fine for RPC payloads, lossy for service
# *state* (a KV dict keyed by ints must restore keyed by ints). Snapshot
# blobs therefore pack state into a tagged tree of serial-safe values
# first, so the round-trip is exact without touching the wire format.

_SCALARS = (int, float, str, bytes)


def _pack(obj: Any):
    if obj is None:
        return ["n"]
    if isinstance(obj, bool):
        return ["b", int(obj)]
    if isinstance(obj, _SCALARS):
        return ["v", obj]
    if isinstance(obj, bytearray):
        return ["v", bytes(obj)]
    if isinstance(obj, list):
        return ["l", [_pack(x) for x in obj]]
    if isinstance(obj, tuple):
        return ["t", [_pack(x) for x in obj]]
    if isinstance(obj, (set, frozenset)):
        return ["s", [_pack(x) for x in sorted(obj, key=repr)]]
    if isinstance(obj, dict):
        return ["d", [[_pack(k), _pack(v)] for k, v in obj.items()]]
    raise TypeError(f"snapshot cannot capture {type(obj).__name__}")


def _unpack(node):
    tag = node[0]
    if tag == "n":
        return None
    if tag == "b":
        return bool(node[1])
    if tag == "v":
        return node[1]
    if tag == "l":
        return [_unpack(x) for x in node[1]]
    if tag == "t":
        return tuple(_unpack(x) for x in node[1])
    if tag == "s":
        return set(_unpack(x) for x in node[1])
    if tag == "d":
        return {_unpack(k): _unpack(v) for k, v in node[1]}
    raise ChannelError(f"corrupt snapshot state tag {tag!r}")


def _class_path(cls: type) -> str:
    return f"{cls.__module__}:{cls.__qualname__}"


def _load_class(path: str) -> type:
    mod_name, _, qualname = path.partition(":")
    obj: Any = importlib.import_module(mod_name)
    for part in qualname.split("."):
        obj = getattr(obj, part)
    return obj


def _capture_state(instance) -> Tuple[Dict[str, Any], List[str]]:
    """The instance's serializable state + the attribute names that were
    skipped (not TLV-encodable, recorded so restore is never silently
    lossy). ``GraphRef`` attributes flatten through the containers graph
    walk; a ``__snapshot__()`` hook overrides the default walk."""
    if hasattr(instance, "__snapshot__"):
        return dict(instance.__snapshot__()), []
    from .marshal import GraphRef
    state: Dict[str, Any] = {}
    skipped: List[str] = []
    for key, val in vars(instance).items():
        if isinstance(val, GraphRef):
            # heap-resident argument graph -> plain Python (§5.6 copy-out)
            state[key] = val.to_python()
            continue
        try:
            _pack(val)
        except (TypeError, ValueError):
            skipped.append(key)
        else:
            state[key] = val
    return state, skipped


def _apply_state(instance, state: Dict[str, Any]) -> None:
    if hasattr(instance, "__restore__"):
        instance.__restore__(dict(state))
    else:
        instance.__dict__.update(state)


def sync_state(src_instance, dst_instance) -> int:
    """Stop-and-copy: re-capture ``src_instance``'s state and apply it to
    ``dst_instance``. Returns the number of attributes synced."""
    state, _ = _capture_state(src_instance)
    _apply_state(dst_instance, state)
    return len(state)


@dataclass
class Snapshot:
    """A portable checkpoint of a served channel.

    ``blob`` is the TLV-encoded service state; ``meta`` records the
    channel shape (heap geometry, fn ids, scope/seal/stream anchors) the
    restore rebuilds against. ``to_bytes``/``from_bytes`` round-trip the
    whole thing through ``core.serial`` for cross-host portability;
    in-process restores reuse the captured class/interceptors directly.
    """

    cls_path: str
    blob: bytes
    meta: Dict[str, Any]
    skipped: List[str] = field(default_factory=list)
    # in-process fast path (not part of the portable bytes)
    _cls: Optional[type] = None
    _interceptors: Tuple = ()

    @property
    def service(self) -> str:
        return self.meta.get("service", "")

    def instantiate(self):
        """A fresh instance carrying the snapshot state (no channel)."""
        cls = self._cls if self._cls is not None \
            else _load_class(self.cls_path)
        inst = cls.__new__(cls)
        _apply_state(inst, _unpack(serial.decode(self.blob)))
        return inst

    def to_bytes(self) -> bytes:
        return serial.encode([SNAPSHOT_VERSION, self.cls_path, self.blob,
                              self.meta, list(self.skipped)])

    @classmethod
    def from_bytes(cls, raw: bytes) -> "Snapshot":
        version, cls_path, blob, meta, skipped = serial.decode(raw)
        if version != SNAPSHOT_VERSION:
            raise ChannelError(
                f"snapshot version {version} not supported "
                f"(this build reads v{SNAPSHOT_VERSION})")
        return cls(cls_path, blob, meta, list(skipped))


def _resolve_channel(target) -> Channel:
    if isinstance(target, Channel):
        return target
    channels = getattr(target, "channels", None)  # lifecycle Endpoint
    if channels:
        return channels[0]
    channel = getattr(target, "channel", None)    # EndpointRecord
    if isinstance(channel, Channel):
        return channel
    raise ChannelError(
        f"snapshot() wants a served Channel or an Endpoint handle, "
        f"got {type(target).__name__}")


def snapshot(target) -> Snapshot:
    """Checkpoint a served channel into a portable :class:`Snapshot`."""
    ch = _resolve_channel(target)
    instance = ch.served_instance
    if instance is None:
        raise ChannelError(
            f"channel {ch.name!r} serves no @service instance — only "
            "served channels can be snapshotted")
    state, skipped = _capture_state(instance)
    blob = serial.encode(_pack(state))
    heaps = {id(c.heap): c.heap for c in ch.connections}
    meta: Dict[str, Any] = {
        "channel": ch.name,
        "service": ch.served_def.name if ch.served_def is not None else "",
        "server_pid": ch.server_pid,
        "heap_pages": ch.heap_pages,
        "page_size": ch.page_size,
        "shared_heap": ch.shared_heap,
        "fn_ids": sorted(ch.functions),
        # observability anchors: what was live at checkpoint time. The
        # wires themselves are not restored (see module docstring).
        "connections": len(ch.connections),
        "pages_used": sum(h.used_pages() for h in heaps.values()),
        "live_streams": [
            {"seq": st.seq, "done": bool(st.done)} for st in ch._streams],
    }
    return Snapshot(_class_path(type(instance)), blob, meta,
                    skipped, _cls=type(instance),
                    _interceptors=ch.serve_interceptors)


@dataclass
class RestoredEndpoint:
    """What ``restore`` hands back: the fresh channel + instance, plus
    the lifecycle handle when ``start=True`` asked for a serve loop."""

    channel: Channel
    instance: Any
    server_pid: int
    endpoint_name: Optional[str] = None
    lifecycle: Optional[Any] = None

    def close(self) -> None:
        if self.lifecycle is not None:
            self.lifecycle.close()
        else:
            self.channel.destroy()


def _fresh_channel_name(orch: Orchestrator, base: str) -> str:
    if base not in orch.channels:
        return base
    n = 1
    while f"{base}~r{n}" in orch.channels:
        n += 1
    return f"{base}~r{n}"


def restore(snap: Snapshot, pod: Optional[str] = None, *,
            router=None, orch: Optional[Orchestrator] = None,
            name: Optional[str] = None,
            server_pid: Optional[int] = None,
            interceptors: Optional[Tuple] = None,
            start: bool = True,
            config=None) -> RestoredEndpoint:
    """Bring a snapshot back as a warm replica.

    ``router`` + ``name`` register the fresh channel under a router
    endpoint (appending to its replica chain); ``orch`` alone restores a
    bare channel. ``pod`` places the new server pid in a coherence
    domain; ``start=True`` serves it from a lifecycle ``Endpoint``
    handle immediately, so the replica is warm before any handoff.
    """
    if router is not None and orch is None:
        orch = router.orch
    if orch is None:
        raise ChannelError("restore() needs router= or orch=")
    pid = orch.alloc_pid() if server_pid is None else server_pid
    ch_name = _fresh_channel_name(orch, snap.meta["channel"])
    ch = Channel(orch, ch_name, pid,
                 heap_pages=snap.meta["heap_pages"],
                 page_size=snap.meta["page_size"],
                 shared_heap=snap.meta["shared_heap"],
                 config=config)
    inst = snap.instantiate()
    itc = snap._interceptors if interceptors is None else tuple(interceptors)
    ch.serve(inst, itc)
    restored_fns = set(ch.functions)
    missing = [f for f in snap.meta["fn_ids"] if f not in restored_fns]
    if missing:
        raise ChannelError(
            f"restore of {snap.service!r} lost handlers {missing}: the "
            "snapshot was taken against a different service definition")
    if pod is not None:
        orch.assign_pod(pid, pod)
    endpoint_name = name
    if router is not None and endpoint_name is not None:
        router.register(endpoint_name, ch, pod)
    lifecycle = None
    if start:
        from .lifecycle import Endpoint
        lifecycle = Endpoint.serve(ch)
    return RestoredEndpoint(ch, inst, pid, endpoint_name, lifecycle)
