"""Typed zero-copy argument marshalling — the unified data plane.

The paper's headline claim is serialization *avoidance* (§4.1, Fig. 11):
an RPC passes a pointer to a pointer-rich structure living in shared
memory; seals and sandboxes restore the isolation that copying used to
provide. This module is the layer that makes that the *default calling
convention* instead of a bytes-in/int-out one:

* ``conn.invoke(fn_id, *values)`` — arguments (arbitrary nested Python
  values, or pre-built ``GraphRef`` container graphs) are materialized
  ONCE as a ``containers`` graph inside a pooled scope, optionally
  sealed, and passed as a single GlobalAddr. Zero serialization.
* On a ``FallbackConnection`` the *same surface* transparently routes by
  value: ``serial.encode`` → one blob copy over the link → decode (the
  §5.6 ``copy_from`` semantics). ``RoutedConnection`` therefore picks
  pointer-passing vs copy per route with no caller change.
* Handler side, ``Channel.add_typed`` handlers receive an ``ArgView``:
  a lazy view that chases pointers on demand. Under a sandboxed request
  every dereference goes through a bounds-checked reader (the §4.3
  wild-pointer attack path surfaces as ``SandboxViolation`` → E_SANDBOX,
  never as server memory disclosure); replies are marshalled back into a
  recycled reply scope the same way.
* ``invoke_serialized`` runs the gRPC-analogue baseline over the SAME
  descriptor ring, so benchmarks/marshal.py measures exactly the
  serialize+copy+deserialize delta of Fig. 11 / Table 1a.

Reply protocol: the ring's 64-bit ``ret`` word carries the GlobalAddr of
either a 16-byte boxed Value (pointer route) or a ``[u32 len][bytes]``
blob (by-value route). Reply scopes are popped from a per-connection
freelist by the server and pushed back by the client after decoding —
the steady state allocates nothing.
"""

from __future__ import annotations

import struct
from typing import Any, Dict, Iterator, List, Optional, Tuple

import time

from . import addr as gaddr
from . import containers as C
from . import serial
from .channel import Connection, E_DEADLINE, E_EXCEPTION, E_OVERLOAD, \
    E_SANDBOX, F_BYVAL, F_SANDBOXED, F_SEALED, F_STREAM, F_TYPED, OK, \
    R_DONE, R_ERR, RpcError, _now_us
from .errors import AllocationError, ChannelError, DeadlineExceeded, \
    InvalidPointer, Overloaded, SandboxViolation, SealViolation, WaitTimeout
from .scope import Scope, ScopePool, create_scope

# Pooled argument scopes: 4 pages (16 KiB with the default page size)
# covers typical pointer-rich documents; bigger argument sets fall back
# to a dedicated right-sized scope.
MARSHAL_SCOPE_PAGES = 4
REPLY_SCOPE_PAGES = 1
_REPLY_FREELIST_MAX = 4
# replies the client never consumed (timeouts, decode errors) are capped:
# past this many live reply scopes the oldest is reclaimed — invoke is
# synchronous, so anything that old is garbage, not in flight
_REPLY_LIVE_MAX = 64

_BOX = struct.Struct("<IIQ")      # boxed reply Value (= containers layout)
_BLOB_HDR = struct.Struct("<I")   # length prefix of a by-value payload

_MISSING = object()


class GraphRef:
    """A pre-built argument-tuple graph resident in a connection's heap.

    ``build_graph(conn, *values)`` materializes the argument tuple once;
    passing the ref to ``invoke`` afterwards is pure pointer passing —
    zero per-call marshalling, the paper's steady-state hot path. On a
    copy-route connection (no shared heap) the ref simply retains the
    plain values and each invoke serializes them, keeping the surface
    identical across routes.
    """

    __slots__ = ("scope", "value", "plain")

    def __init__(self, scope: Optional[Scope], value: Optional[C.Value],
                 plain: Optional[list] = None):
        self.scope = scope
        self.value = value
        self.plain = plain

    @property
    def root(self) -> int:
        return self.value[1]

    @property
    def heap(self):
        return None if self.scope is None else self.scope.heap

    def to_python(self) -> list:
        """The argument tuple as plain values (§5.6 copy-out half)."""
        if self.scope is None:
            return list(self.plain)
        return C.to_python(self.scope.heap, self.value)

    def destroy(self) -> None:
        if self.scope is not None and self.scope.live:
            self.scope.destroy()


class ArgView:
    """Uniform lazy view over typed RPC arguments.

    Graph-backed (pointer route): every access walks the ``containers``
    graph through a reader — the connection heap when trusted, a
    bounds-checked sandbox reader when the request is sandboxed. Nothing
    is deserialized; the handler touches only what it dereferences.

    Python-backed (by-value route): wraps the already-decoded object so
    the same handler code serves both routes.

    Scalars (ints, floats, strings, None) unwrap to Python values on
    access; Vec/Map nodes come back as nested ``ArgView``s.
    """

    __slots__ = ("_reader", "_val", "_py")

    def __init__(self, reader, val: Optional[C.Value], py=_MISSING):
        self._reader = reader
        self._val = val
        self._py = py

    # -- constructors ----------------------------------------------------
    @classmethod
    def graph(cls, reader, value: C.Value) -> "ArgView":
        return cls(reader, value)

    @classmethod
    def python(cls, obj) -> "ArgView":
        return cls(None, None, obj)

    # -- wrapping --------------------------------------------------------
    def _wrap(self, v: C.Value):
        tag, p = v
        if tag == C.T_NULL:
            return None
        if tag == C.T_I64:
            return p - (1 << 64) if p >= (1 << 63) else p
        if tag == C.T_F64:
            return C._unpack_f64(p)
        if tag == C.T_STR:
            return C.read_str(self._reader, p)
        if tag == C.T_BYTES:
            return C.read_bytes(self._reader, p)
        return ArgView(self._reader, v)

    @staticmethod
    def _wrap_py(obj):
        if isinstance(obj, (dict, list, tuple)):
            return ArgView.python(obj)
        return obj

    # -- the access surface ----------------------------------------------
    def __len__(self) -> int:
        if self._reader is None:
            return len(self._py)
        tag, p = self._val
        if tag == C.T_VEC:
            return C.vec_len(self._reader, p)
        if tag == C.T_MAP:
            return C.map_len(self._reader, p)
        raise InvalidPointer(f"len() of non-container value tag {tag}")

    def __getitem__(self, key):
        if self._reader is None:
            return self._wrap_py(self._py[key])
        tag, p = self._val
        if isinstance(key, str):
            if tag != C.T_MAP:
                raise InvalidPointer(f"string index into value tag {tag}")
            v = C.map_get(self._reader, p, key)
            if v is None:
                raise KeyError(key)
            return self._wrap(v)
        if tag != C.T_VEC:
            raise InvalidPointer(f"integer index into value tag {tag}")
        n = C.vec_len(self._reader, p)
        if key < 0:
            key += n
        return self._wrap(C.vec_get(self._reader, p, key))

    def get(self, key: str, default=None):
        if self._reader is None:
            return self._wrap_py(self._py.get(key, default))
        tag, p = self._val
        if tag != C.T_MAP:
            raise InvalidPointer(f"get() on value tag {tag}")
        v = C.map_get(self._reader, p, key)
        return default if v is None else self._wrap(v)

    def keys(self) -> List[str]:
        if self._reader is None:
            return list(self._py.keys())
        tag, p = self._val
        if tag != C.T_MAP:
            raise InvalidPointer(f"keys() on value tag {tag}")
        return [k for k, _ in C.map_items(self._reader, p)]

    def __iter__(self) -> Iterator:
        if self._reader is None:
            if isinstance(self._py, dict):
                return iter(self._py.keys())
            return (self._wrap_py(v) for v in self._py)
        tag, p = self._val
        if tag == C.T_MAP:
            return iter(self.keys())
        if tag == C.T_VEC:
            return (self._wrap(C.vec_get(self._reader, p, i))
                    for i in range(C.vec_len(self._reader, p)))
        raise InvalidPointer(f"iteration over value tag {tag}")

    def __contains__(self, key: str) -> bool:
        if self._reader is None:
            if not isinstance(self._py, dict):
                raise InvalidPointer("`in` requires a map value")
            return key in self._py
        tag, p = self._val
        if tag != C.T_MAP:
            raise InvalidPointer(f"`in` on value tag {tag}")
        return C.map_get(self._reader, p, key) is not None

    def to_python(self):
        """Materialize the whole subtree (the explicit opt-in to a full
        deserialize — what the lazy surface otherwise avoids)."""
        if self._reader is None:
            obj = self._py
            if isinstance(obj, tuple):
                return list(obj)
            return obj
        return C.to_python(self._reader, self._val)


# ---------------------------------------------------------------------------
# argument marshalling (client side)
# ---------------------------------------------------------------------------
def _build_arg(scope: Scope, v, pid: int, force_copy: bool) -> C.Value:
    """One argument → Value in ``scope``.

    A ``GraphRef`` living in the same heap is pointer-embedded for free
    (the whole point); one in a foreign heap — or any graph under a
    sandboxed call, whose sandbox covers only the call scope — is
    ``deep_copy``'d into the scope (§5.6 ``copy_from``).
    """
    if isinstance(v, GraphRef):
        if v.scope is None:   # plain ref: rebuild its retained values
            return C.build_value(scope, v.plain, pid)
        if v.scope.heap is scope.heap and not force_copy:
            return v.value
        return C.deep_copy(v.scope.heap, scope, v.value, pid)
    return C.build_value(scope, v, pid)


def marshal_args(scope: Scope, args: Tuple, pid: int = 0,
                 force_copy: bool = False) -> int:
    """Materialize the argument tuple as a Vec graph; returns its root."""
    vals = [_build_arg(scope, v, pid, force_copy) for v in args]
    return C.build_vec(scope, vals, pid)[1]


def build_graph(conn, *values) -> GraphRef:
    """Materialize an argument tuple once in ``conn``'s heap.

    The returned ``GraphRef`` can be passed to ``invoke`` any number of
    times — each call is then pure pointer passing. Works on CXL and
    routed connections (``RoutedConnection.build_graph`` delegates here
    against the live target); a copy-route target gets a plain-value ref
    since there is no shared heap to materialize into."""
    heap = getattr(conn, "heap", None)
    if heap is None:  # FallbackConnection: the route copies either way
        return GraphRef(None, None, plain=[_to_plain(v) for v in values])
    pages = MARSHAL_SCOPE_PAGES
    while True:
        scope = conn.create_scope(pages * heap.page_size)
        try:
            root = marshal_args(scope, values, pid=conn.client_pid)
            return GraphRef(scope, (C.T_VEC, root))
        except AllocationError:
            scope.destroy()
            if pages > (1 << 16):
                raise
            pages *= 4
        except BaseException:
            scope.destroy()   # unsupported value etc. — no page leak
            raise


def _marshal_pool(conn: Connection) -> ScopePool:
    pool = conn._marshal_pool
    if pool is None or pool.scope_pages != MARSHAL_SCOPE_PAGES:
        pool = conn._marshal_pool = ScopePool(
            conn.heap, MARSHAL_SCOPE_PAGES, owner=conn.client_pid,
            seals=conn.seals)
    return pool


def _pool_recycle(conn: Connection, scope: Scope, pooled: bool,
                  seal_idx: Optional[int] = None) -> None:
    """Return a marshal scope to its pool, tolerating a connection that
    closed mid-call (live migration / replica failover tears the wires
    down while a straggler op is still in flight). With the pool already
    gone the scope is destroyed instead, so its page range never leaks
    into the dying heap."""
    if pooled:
        pool = conn._marshal_pool
        if pool is not None:
            if seal_idx is not None:
                pool.push_sealed(scope, seal_idx)
            else:
                pool.push(scope)
        elif scope.live:
            try:
                scope.destroy()
            except Exception:
                pass  # already-torn-down heap; nothing left to leak into
    elif scope.live:
        scope.destroy()


def _fill_pooled(conn: Connection, pid: int, fill) -> Tuple[Any, Scope, bool]:
    """Run ``fill(scope)`` in a pooled marshal scope, retrying in a
    geometrically larger dedicated scope on overflow. Returns
    (fill result, scope, pooled?); exception-safe — a failing fill never
    leaks its scope."""
    pool = _marshal_pool(conn)
    scope = pool.pop()
    try:
        return fill(scope), scope, True
    except AllocationError:
        pool.push(scope)
    except BaseException:
        pool.push(scope)      # bad value (TypeError, …) — no scope leak
        raise
    pages = MARSHAL_SCOPE_PAGES * 4
    while True:
        scope = create_scope(conn.heap, pages * conn.heap.page_size,
                             owner=pid)
        try:
            return fill(scope), scope, False
        except AllocationError:
            scope.destroy()
            if pages > (1 << 16):
                raise
            pages *= 4
        except BaseException:
            scope.destroy()
            raise


def _pooled_marshal(conn: Connection, args: Tuple, pid: int,
                    force_copy: bool) -> Tuple[int, Scope, bool]:
    """(root, scope, pooled?) — pooled fast path, dedicated on overflow."""
    return _fill_pooled(
        conn, pid, lambda scope: marshal_args(scope, args, pid, force_copy))


# ---------------------------------------------------------------------------
# reply marshalling (server side) + decoding (client side)
# ---------------------------------------------------------------------------
def _reply_heap(conn):
    heap = getattr(conn, "heap", None)
    return heap if heap is not None else conn.client.heap


def _pop_reply_scope(conn, nbytes: int) -> Tuple[Scope, bool]:
    heap = _reply_heap(conn)
    if nbytes <= REPLY_SCOPE_PAGES * heap.page_size:
        free = conn._reply_free
        if free:
            s = free.pop()
            tr = heap._tracer
            if tr is not None:
                # freelist hand-off: the recycler's accesses (the client
                # reading the previous reply) happen-before this reuse
                tr.sync_acquire(("scope", id(s)))
            s.reset()
            return s, True
        return create_scope(heap, REPLY_SCOPE_PAGES * heap.page_size), True
    return create_scope(heap, nbytes), False


def _release_reply_scope(conn, scope: Scope) -> None:
    """The one push-or-destroy policy for reply scopes."""
    if scope.num_pages == REPLY_SCOPE_PAGES and \
            len(conn._reply_free) < _REPLY_FREELIST_MAX:
        tr = _reply_heap(conn)._tracer
        if tr is not None:
            tr.sync_release(("scope", id(scope)))
        conn._reply_free.append(scope)
    elif scope.live:
        scope.destroy()


def _track_reply(conn, addr: int, scope: Scope) -> None:
    live = conn._reply_live
    if len(live) >= _REPLY_LIVE_MAX:
        # a client that errored before decoding (timeout, link failure)
        # strands its reply scope here; reclaim the oldest so repeated
        # errors cannot pin the channel heap
        oldest = next(iter(live))
        _release_reply_scope(conn, live.pop(oldest))
    live[addr] = scope


def _recycle_reply(conn, addr: int) -> None:
    scope = conn._reply_live.pop(addr, None)
    if scope is not None:
        _release_reply_scope(conn, scope)


def _write_reply_graph(ctx, ret) -> int:
    """Marshal a handler's return value as a boxed Value + graph."""
    conn = ctx.conn
    scope, _pooled = _pop_reply_scope(conn, REPLY_SCOPE_PAGES)
    heap = _reply_heap(conn)
    nbytes = REPLY_SCOPE_PAGES * heap.page_size
    while True:
        try:
            val = C.build_value(scope, ret)
            box = scope.alloc(C.VALUE_SIZE)
            scope.heap.write(box, _BOX.pack(val[0], 0, val[1]))
            break
        except AllocationError:
            # big reply: retry in a geometrically larger dedicated scope
            # (serial length is NOT a bound — e.g. None is 1 B on the
            # wire but a 16 B containers Value)
            _release_reply_scope(conn, scope)
            nbytes *= 8
            if nbytes > heap.num_pages * heap.page_size:
                raise
            scope, _pooled = _pop_reply_scope(conn, nbytes)
    _track_reply(conn, box, scope)
    return box


def _read_reply_graph(conn, box: int):
    heap = conn.heap
    tag, _, payload = _BOX.unpack(bytes(heap.read(box, C.VALUE_SIZE)))
    out = C.to_python(heap, (tag, payload))
    _recycle_reply(conn, box)
    return out


def _write_reply_blob(ctx, raw: bytes) -> int:
    conn = ctx.conn
    scope, _pooled = _pop_reply_scope(conn, _BLOB_HDR.size + len(raw))
    a = scope.alloc(_BLOB_HDR.size + len(raw))
    # privileged runtime store — the reply lands outside the handler's
    # sandbox, like librpcool writing after SB_END
    ctx._daemon_write(a, _BLOB_HDR.pack(len(raw)) + raw)
    _track_reply(conn, a, scope)
    return a


def _read_blob(reader, a: int, psize: int) -> bytes:
    n = _BLOB_HDR.unpack(bytes(reader.read(a, _BLOB_HDR.size)))[0]
    return bytes(reader.read(gaddr.add(a, _BLOB_HDR.size, psize), n))


# ---------------------------------------------------------------------------
# the typed handler wrapper (receiver half)
# ---------------------------------------------------------------------------
def _reader_for(ctx):
    """The §4.4 contract: a sandboxed request chases pointers through a
    bounds-checked reader (one range check per dereference — the MMU
    fault check under the MPK cost model); a trusted request gets the
    raw-view reader over the whole heap (hardware loads cost nothing
    extra once the mapping exists). A fallback-route ctx reads through
    itself so page faults keep migrating pages."""
    sb = ctx.sandbox
    if sb is not None:
        return C.fast_reader_for_sandbox(sb)
    heap = ctx.heap()
    if getattr(ctx, "conn", None) is not None and \
            getattr(ctx.conn, "server", None) is not None:
        return ctx   # DSM node: reads must fault pages across the link
    return C.FastReader(heap)


def typed_handler(fn):
    """Wrap ``fn(ctx, args: ArgView) -> value`` as a raw ring handler.

    The wrapper dispatches on the descriptor flags, so ONE registration
    serves both routes: F_TYPED alone = pointer-passing (graph view),
    F_TYPED|F_BYVAL = serialized by-value (fallback route / baseline).
    """
    def wrapper(ctx, arg: int) -> int:
        flags = ctx.flags
        if not flags & F_TYPED:
            raise ChannelError(
                "typed handler called through the raw data path "
                "(use conn.invoke, not conn.call)")
        if flags & F_STREAM:
            # streaming reply: hand the transport a ServerStream — the
            # slot completes only when the chunk chain ends
            return _start_stream(ctx, fn, arg, flags)
        if flags & F_BYVAL:
            heap = ctx.heap()
            raw = _read_blob(ctx, arg, heap.page_size)
            view = ArgView.python(serial.decode(raw))   # full deserialize
            ret = fn(ctx, view)
            return _write_reply_blob(ctx, serial.encode(ret))
        view = ArgView.graph(_reader_for(ctx), (C.T_VEC, arg))
        try:
            ret = fn(ctx, view)
        except InvalidPointer as e:
            if ctx.sandbox is not None:
                # the §4.3 wild-pointer attack path: a bad pointer inside
                # a sandboxed request is a sandbox fault (→ E_SANDBOX
                # reply), never an exception class that leaks less intent
                raise SandboxViolation(str(e)) from e
            raise
        return _write_reply_graph(ctx, ret)

    wrapper.__wrapped__ = fn
    wrapper.typed = True
    return wrapper


# ---------------------------------------------------------------------------
# pipelined futures (invoke_async / gather)
# ---------------------------------------------------------------------------
_PENDING, _DONE, _FAILED, _CANCELLED = range(4)


def _deadline_word(deadline: Optional[float]) -> int:
    """Relative seconds of budget → the descriptor's absolute-µs word."""
    return 0 if deadline is None else _now_us() + int(deadline * 1e6)


class RpcFuture:
    """One in-flight typed RPC on a CXL ring connection.

    Many futures may be outstanding on one connection (the whole point of
    per-thread MPK permissions, §5.2) and they complete in whatever order
    the server drains slots; ``gather`` consumes them as they land. A
    future owns its marshal scope until settlement: ``result`` releases
    it back to the pool, ``cancel``/terminal errors release it exactly
    once, and a wait timeout leaves it alive (the server may still be
    reading the arguments mid-flight).
    """

    __slots__ = ("conn", "fn_id", "token", "_scope", "_pooled", "_sealed",
                 "_timeout", "_deadline_us", "_state", "_value", "_exc",
                 "_scope_released", "_batch_release")

    def __init__(self, conn, fn_id: int, token: Tuple[int, int],
                 scope: Optional[Scope], pooled: bool, sealed: bool,
                 timeout: float, deadline_us: int,
                 batch_release: bool = False):
        self.conn = conn
        self.fn_id = fn_id
        self.token = token
        self._scope = scope
        self._pooled = pooled
        self._sealed = sealed
        self._timeout = timeout
        self._deadline_us = deadline_us
        self._state = _PENDING
        self._value = None
        self._exc: Optional[BaseException] = None
        self._scope_released = scope is None
        # §5.3 composed with pipelining: queue this future's seal release
        # for the window flush (``gather``/``end_seal_window``) instead
        # of paying a permission epoch at settlement
        self._batch_release = batch_release

    # -- scope hygiene (the one-shot close()/reap cleanup hook) ----------
    def _release_scope_once(self) -> None:
        if self._scope_released:
            return
        self._scope_released = True
        scope = self._scope
        _pool_recycle(self.conn, scope, self._pooled)

    def _fail(self, exc: BaseException) -> None:
        self._state = _FAILED
        self._exc = exc
        self._release_scope_once()

    # -- the future surface ----------------------------------------------
    def done(self) -> bool:
        """Non-blocking: True once ``result`` will not wait."""
        return self._state != _PENDING or self.conn.poll(self.token)

    def _kick(self) -> None:
        """Transport hook: push any batched flight onto the wire (no-op
        on the CXL ring — the descriptor was posted at invoke time)."""

    def cancel(self) -> bool:
        """Abandon the call. Best-effort (an SPSC slot cannot be
        un-posted, so the server may still execute the handler); the
        reply scope and ring slot are reaped the moment the completion
        lands, and the marshal scope is recycled exactly once."""
        if self._state != _PENDING:
            return False
        conn = self.conn
        pending = conn._pending_async.get(self.token[0])
        self._state = _CANCELLED
        self._exc = ChannelError("future cancelled")
        if pending is not None:
            pending.cleanup = self._release_scope_once
            conn._abandon(self.token, pending)
        else:
            self._release_scope_once()
        return True

    def result(self, timeout: Optional[float] = None):
        """Block (with the §5.8 client back-off) until the reply lands;
        returns the decoded value or raises the RPC's error. A timeout
        raises ``ChannelError`` but leaves the future pending — call
        again, or ``cancel()`` to hand the slot to the reaper."""
        if self._state == _DONE:
            return self._value
        if self._state != _PENDING:
            raise self._exc
        conn = self.conn
        tmo = self._timeout if timeout is None else timeout
        if self._deadline_us:
            tmo = min(tmo, max(0.0,
                               self._deadline_us * 1e-6 - time.monotonic()))
        try:
            ret = conn.wait(self.token, sealed=self._sealed,
                            batch_release=self._batch_release, timeout=tmo)
        except (DeadlineExceeded, Overloaded, RpcError) as e:
            # terminal typed failures: the reply landed (or the server
            # shed the request with E_OVERLOAD) — never a wait timeout
            self._fail(e)
            raise
        except ChannelError as e:
            if not conn.closed and \
                    self.token[0] in conn._pending_async:
                if self._deadline_us and _now_us() > self._deadline_us:
                    # the REQUEST deadline lapsed mid-wait: terminal.
                    # The slot cannot be un-posted, so hand it to the
                    # reaper (scope recycled when the completion lands)
                    # instead of leaving a zombie waiter.
                    exc = DeadlineExceeded("RPC deadline lapsed")
                    self._state = _FAILED
                    self._exc = exc
                    pending = conn._pending_async[self.token[0]]
                    pending.cleanup = self._release_scope_once
                    conn._abandon(self.token, pending)
                    raise exc from e
                raise   # pure wait timeout: still in flight, retryable
            self._fail(e)
            raise
        self._release_scope_once()
        self._value = _read_reply_graph(conn, ret)
        self._state = _DONE
        return self._value


def invoke_async_cxl(conn: Connection, fn_id: int, args: Tuple,
                     sealed: bool = False, sandboxed: bool = False,
                     batch_release: bool = False,
                     deadline: Optional[float] = None,
                     timeout: float = 10.0) -> RpcFuture:
    """Pipelined typed invoke on the shared-memory ring: marshal (or
    pointer-pass a prebuilt graph), post, return — the reply is decoded
    whenever the future is settled. Up to ring-capacity invokes may be
    in flight per connection. ``batch_release`` queues each sealed
    future's release for the window flush (one permission epoch per
    ``gather``, §5.3) instead of one epoch per settlement."""
    deadline_us = _deadline_word(deadline)

    if len(args) == 1 and isinstance(args[0], GraphRef):
        g = args[0]
        if g.scope is not None and g.scope.heap is conn.heap:
            conn.n_invokes += 1
            token = conn.call_async(fn_id, g.root, scope=g.scope,
                                    sealed=sealed, sandboxed=sandboxed,
                                    flags_extra=F_TYPED,
                                    deadline_us=deadline_us)
            fut = RpcFuture(conn, fn_id, token, None, False, sealed,
                            timeout, deadline_us,
                            batch_release=batch_release)
            conn._track_async(token, sealed=sealed, typed=True)
            return fut
        args = tuple(g.to_python())

    root, scope, pooled = _pooled_marshal(conn, args, conn.client_pid,
                                          force_copy=sandboxed or sealed)
    try:
        token = conn.call_async(fn_id, root, scope=scope, sealed=sealed,
                                sandboxed=sandboxed, flags_extra=F_TYPED,
                                deadline_us=deadline_us)
    except BaseException:
        _pool_recycle(conn, scope, pooled)
        raise
    conn.n_invokes += 1
    conn.marshal_bytes += scope.used_bytes()
    fut = RpcFuture(conn, fn_id, token, scope, pooled, sealed,
                    timeout, deadline_us, batch_release=batch_release)
    # close()/reap cleanup hook: drain this future's scope exactly once
    conn._track_async(token, sealed=sealed, typed=True,
                      cleanup=fut._release_scope_once)
    return fut


def gather(futures, timeout: float = 10.0) -> list:
    """Settle a batch of futures, consuming completions **as they land**
    (out-of-order draining — a slow first RPC never blocks the reaping
    of the seven behind it). Returns results in the order given; the
    first failed future raises after everything already completed was
    drained."""
    results = [None] * len(futures)
    pending = dict(enumerate(futures))
    deadline = time.monotonic() + timeout
    # Window epoch batching (§5.3 composed with pipelining): futures
    # created with ``batch_release=True`` queue their seal releases
    # instead of bumping one permission epoch each; the whole window is
    # flushed in ONE epoch once the gather drains (see finally below).
    window_conns = []
    for f in futures:
        conn = getattr(f, "conn", None)
        if (getattr(f, "_batch_release", False) and conn is not None
                and conn not in window_conns):
            window_conns.append(conn)
    try:
        _gather_drain(results, pending, deadline, timeout)
    finally:
        for conn in window_conns:
            end = getattr(conn, "end_seal_window", None)
            if end is not None:
                end()
    return results


def _gather_drain(results, pending, deadline, timeout) -> None:
    failed: Optional[BaseException] = None
    while pending:
        progressed = False
        for i, f in list(pending.items()):
            if not f.done():
                continue
            del pending[i]
            progressed = True
            try:
                results[i] = f.result(timeout=timeout)
            except BaseException as e:
                failed = failed or e
        if not pending:
            break
        if failed is not None:
            break   # drain what's already done, then surface the error
        if time.monotonic() > deadline:
            raise ChannelError(f"gather timed out with {len(pending)} "
                               "futures unsettled")
        if not progressed:
            # nothing ready: block on the oldest pending future in a
            # bounded slice (its result() waits through the connection's
            # §5.8 wait policy — no busy-poll here) after kicking any
            # batched flight onto the wire
            i, f = next(iter(pending.items()))
            f._kick()
            slice_s = min(0.05, max(0.005,
                                    deadline - time.monotonic()))
            try:
                results[i] = f.result(timeout=slice_s)
                del pending[i]
            except (DeadlineExceeded, RpcError) as e:
                failed = failed or e
                del pending[i]
            except WaitTimeout:
                pass   # wait-timeout slice: still in flight, re-loop
            except BaseException as e:
                failed = failed or e
                del pending[i]
    if failed is not None:
        raise failed


# ---------------------------------------------------------------------------
# streaming replies — generation-tagged chunk chains (invoke_stream)
# ---------------------------------------------------------------------------
# A streaming RPC posts ONE descriptor whose argument is a *stream anchor*
# living in the request scope; the server grows a singly-linked chain of
# chunks off the anchor while the call is still in flight — each chunk is
# one pointer flip (store the new chunk's address into the predecessor's
# ``next`` word), the same publication primitive the paper's reply path
# uses. The ring slot completes only when the chain ends, so ordinary
# sweeps keep working and close()/reap hygiene is inherited unchanged.
#
#   anchor (32 B, client scope): [head u64][gen u32][consumed u32]
#                                [args u64][window u32][pad u32]
#   chunk  (32 B + payload):     [next u64][gen u32][seq u32][cflags u32]
#                                [aux u32][vpayload u64]
#
# ``gen`` tags every chunk with the call's generation so a chunk left
# over from an abandoned stream can never be mistaken for a live one.
# ``consumed`` is the client's running count of value chunks taken — the
# server stalls once ``seq - consumed`` reaches ``window`` (bounded-chunk
# backpressure); the sentinel value cancels the stream. CH_VALUE chunks
# carry a boxed containers Value in ``aux``/``vpayload`` (pointer route)
# or a blob address + length (by-value route); CH_ERR carries the RPC
# status in ``aux``.

_ANCHOR = struct.Struct("<QIIQII")   # head, gen, consumed, args, window, pad
_CHUNK = struct.Struct("<QIIIIQ")    # next, gen, seq, cflags, aux, vpayload
CHUNK_HDR_BYTES = _CHUNK.size
_ANCHOR_CONSUMED_OFF = 12
_U64 = struct.Struct("<Q")
_U32 = struct.Struct("<I")

CH_VALUE = 0
CH_END = 1
CH_ERR = 2

DEFAULT_STREAM_WINDOW = 16    # CXL push mode: max unconsumed chunks
STREAM_FLIGHT_CHUNKS = 8      # fallback pull mode: chunks per wire flight
_CHAIN_FREELIST_MAX = 32
_STREAM_CANCEL = 0xFFFFFFFF   # consumed-word sentinel: client cancelled


def _pop_chain_scope(conn, nbytes: int) -> Scope:
    """A recycled chunk-chain scope (one page) or a dedicated right-sized
    one for oversized chunk payloads."""
    heap = _reply_heap(conn)
    if nbytes <= REPLY_SCOPE_PAGES * heap.page_size:
        free = conn._chain_free
        if free:
            s = free.pop()
            s.reset()
            return s
        s = create_scope(heap, REPLY_SCOPE_PAGES * heap.page_size)
    else:
        s = create_scope(heap, nbytes)
    tr = heap._tracer
    if tr is not None:
        # chunk chains are synchronization fabric: the next-word flips
        # race with the consumer's chase by design — ordering comes from
        # the explicit ("chk", ...) publish/consume edges
        tr.sync_pages(heap, *s.page_range())
    return s


def _release_chain_scope(conn, scope: Scope) -> None:
    if scope.num_pages == REPLY_SCOPE_PAGES and \
            len(conn._chain_free) < _CHAIN_FREELIST_MAX:
        conn._chain_free.append(scope)
    elif scope.live:
        scope.destroy()


def _recycle_chunk(conn, addr: int) -> None:
    scope = conn._reply_live.pop(addr, None)
    if scope is not None:
        _release_chain_scope(conn, scope)


class ServerStream:
    """Server half of one streaming reply: the handler's generator plus
    the growing chunk chain.

    Created by ``typed_handler`` when the descriptor carries F_STREAM and
    registered with the serving transport, which *pumps* it: push mode
    (CXL serve loops) emits until the client's bounded window fills, pull
    mode (fallback flights) emits exactly the requested batch. Terminal
    chunks (CH_END / CH_ERR) complete the ring slot, release the seal
    hold, and close the generator.
    """

    _server_stream = True

    __slots__ = ("ctx", "it", "anchor", "gen_tag", "window", "byval",
                 "conn", "ring", "slot", "seal_idx", "flags",
                 "_sc_start", "_sc_count", "_consumed_addr",
                 "seq", "prev", "done", "release_cb", "burst")

    def __init__(self, ctx, it, anchor: int, gen_tag: int, window: int,
                 byval: bool):
        self.ctx = ctx
        self.it = it
        self.anchor = anchor
        self.gen_tag = gen_tag
        self.window = window or DEFAULT_STREAM_WINDOW
        self.byval = byval
        self.conn = None
        self.ring = None
        self.slot = 0
        self.seal_idx = 0
        self.flags = 0
        self._sc_start = 0
        self._sc_count = 0
        self._consumed_addr = 0
        self.seq = 0     # value chunks emitted
        self.prev = 0    # last published chunk (0 = publish to anchor)
        self.done = False
        # admission-gate release (§5.4): a stream stays admitted until
        # its chain ends; every terminal path funnels through abort()
        self.release_cb = None
        # push-mode per-pump emission cap; None = a full window. Serving
        # transports lower it (Channel.stream_pump_burst) when the
        # generators behind concurrent streams share state — e.g. a
        # continuous-batching scheduler — and one stream running a whole
        # window ahead per pump would defeat the batching.
        self.burst = None

    def bind(self, conn, ring, slot: int, seal_idx: int, flags: int,
             sc_start: int, sc_count: int) -> None:
        """Attach the transport half (called by the serve path once the
        descriptor's slot identity is known)."""
        self.conn = conn
        self.ring = ring
        self.slot = slot
        self.seal_idx = seal_idx
        self.flags = flags
        self._sc_start = sc_start
        self._sc_count = sc_count
        self._consumed_addr = gaddr.add(
            self.anchor, _ANCHOR_CONSUMED_OFF,
            _reply_heap(conn).page_size)

    # -- pumping ---------------------------------------------------------
    def pump(self, max_chunks: Optional[int] = None,
             collect: Optional[List[int]] = None) -> int:
        """Advance the generator. Push mode (``max_chunks=None``): emit
        until the client's window is full or the stream ends. Pull mode:
        emit up to ``max_chunks`` value chunks, appending every emitted
        chunk address to ``collect``. Returns the chunks emitted."""
        if self.done:
            return 0
        emitted = 0
        while True:
            if max_chunks is not None:
                if emitted >= max_chunks:
                    break
            else:
                if emitted >= (self.burst or self.window):
                    # push-mode fairness: one pump emits at most a
                    # window's worth (or the transport's tighter burst)
                    # even when a fast consumer keeps the window open —
                    # otherwise the serving thread runs THIS generator
                    # to completion while every other stream (and the
                    # continuous-batching scheduler behind them)
                    # starves. The sweep re-pumps next pass.
                    break
                try:
                    consumed = self._read_consumed()
                except (InvalidPointer, ChannelError):
                    # the client closed mid-stream and its anchor pages
                    # went back to the heap: drop the stream instead of
                    # killing the serving thread
                    self.abort()
                    break
                if consumed == _STREAM_CANCEL:
                    self._complete(R_ERR, E_EXCEPTION)
                    break
                if self.seq - consumed >= self.window:
                    break   # backpressure: bounded chunk window is full
            dl = getattr(self.ctx, "deadline_us", 0)
            if dl and _now_us() > dl:
                self._finish(CH_ERR, E_DEADLINE, collect)
                emitted += 1
                break
            try:
                value = self._next_value()
            except StopIteration:
                self._finish(CH_END, OK, collect)
                emitted += 1
                break
            except DeadlineExceeded:
                self._finish(CH_ERR, E_DEADLINE, collect)
                emitted += 1
                break
            except Overloaded as e:
                # pool-pressure shed from inside the handler (the §5.4
                # retry-after path): the terminal chunk's value word
                # carries the suggested back-off in microseconds
                self._finish(CH_ERR, E_OVERLOAD, collect,
                             val=max(0, int(e.retry_after_s * 1e6)))
                emitted += 1
                break
            except SandboxViolation:
                self._finish(CH_ERR, E_SANDBOX, collect)
                emitted += 1
                break
            except InvalidPointer:
                status = E_SANDBOX if self.flags & F_SANDBOXED \
                    else E_EXCEPTION
                self._finish(CH_ERR, status, collect)
                emitted += 1
                break
            except BaseException:
                self._finish(CH_ERR, E_EXCEPTION, collect)
                emitted += 1
                break
            try:
                self._emit_value(value, collect)
            except (InvalidPointer, ChannelError):
                # the client tore the connection down mid-stream: the
                # chain pages are gone — just drop the generator
                self.abort()
                break
            emitted += 1
        return emitted

    def _next_value(self):
        if self.flags & F_SANDBOXED and self._sc_count:
            # re-enter the request's sandbox for this slice of handler
            # code (cached key ⇒ the O(1) PKRU-write path, §5.2)
            with self.conn.sandboxes.enter(self._sc_start,
                                           self._sc_count) as sb:
                self.ctx.sandbox = sb
                return next(self.it)
        return next(self.it)

    def _read_consumed(self) -> int:
        heap = _reply_heap(self.conn)
        tr = heap._tracer
        if tr is not None:
            tr.sync_acquire(("cons", tr._space(heap), self._consumed_addr))
        return _U32.unpack(bytes(heap.read(self._consumed_addr, 4)))[0]

    # -- chunk emission --------------------------------------------------
    def _emit_value(self, value, collect) -> None:
        conn = self.conn
        if self.byval:
            raw = serial.encode(value)
            scope = _pop_chain_scope(conn, _CHUNK.size + len(raw))
            hdr = scope.alloc(_CHUNK.size)
            blob = scope.alloc(len(raw))
            self.ctx._daemon_write(blob, raw)
            self.ctx._daemon_write(hdr, _CHUNK.pack(
                0, self.gen_tag, self.seq, CH_VALUE, len(raw), blob))
        else:
            scope, hdr, val = self._build_graph_chunk(conn, value)
            self.ctx._daemon_write(hdr, _CHUNK.pack(
                0, self.gen_tag, self.seq, CH_VALUE, val[0], val[1]))
        conn._reply_live[hdr] = scope
        self.seq += 1
        self._publish(hdr, collect)

    def _build_graph_chunk(self, conn, value):
        heap = _reply_heap(conn)
        nbytes = REPLY_SCOPE_PAGES * heap.page_size
        scope = _pop_chain_scope(conn, nbytes)
        while True:
            try:
                hdr = scope.alloc(_CHUNK.size)
                return scope, hdr, C.build_value(scope, value)
            except AllocationError:
                # big chunk: geometrically larger dedicated scope
                _release_chain_scope(conn, scope)
                nbytes *= 8
                if nbytes > heap.num_pages * heap.page_size:
                    raise
                scope = _pop_chain_scope(conn, nbytes)

    def _publish(self, hdr: int, collect) -> None:
        """The pointer flip: store the chunk's address into its
        predecessor's ``next`` word (or the anchor's head)."""
        target = self.anchor if self.prev == 0 else self.prev
        heap = _reply_heap(self.conn)
        tr = heap._tracer
        if tr is not None:
            # the pointer flip publishes the chunk: everything written
            # into it happens-before the client's chase of this word
            tr.sync_release(("chk", tr._space(heap), hdr))
        self.ctx._daemon_write(target, _U64.pack(hdr))
        self.prev = hdr
        if collect is not None:
            collect.append(hdr)

    # -- termination -----------------------------------------------------
    def _finish(self, cflags: int, status: int, collect,
                val: int = 0) -> None:
        conn = self.conn
        try:
            scope = _pop_chain_scope(conn, _CHUNK.size)
            hdr = scope.alloc(_CHUNK.size)
            self.ctx._daemon_write(hdr, _CHUNK.pack(
                0, self.gen_tag, self.seq, cflags, status, val))
            conn._reply_live[hdr] = scope
            self._publish(hdr, collect)
        except (InvalidPointer, ChannelError):
            self.abort()
            return
        self._complete(R_DONE if cflags == CH_END else R_ERR, status, val)

    def _complete(self, state: int, status: int, ret: int = 0) -> None:
        if self.flags & F_SEALED:
            try:
                self.conn.seals.mark_complete(self.seal_idx)
            except SealViolation:
                pass
        # the ret word mirrors the terminal chunk's value word (e.g. the
        # E_OVERLOAD retry-after µs) so a client that settles via the
        # slot sees the same typed hint as one that read the chain
        tr = _reply_heap(self.conn)._tracer
        if tr is not None:
            tr.sync_release(("rep", id(self.ring), self.slot))
        self.ring.complete(self.slot, ret, state, status)
        self.abort()

    def abort(self) -> None:
        """Drop the stream without touching the ring (client gone, or
        terminal chunk already published)."""
        self.done = True
        cb, self.release_cb = self.release_cb, None  # fire exactly once
        if cb is not None:
            cb()
        try:
            self.it.close()
        except Exception:
            pass


def _start_stream(ctx, fn, arg: int, flags: int) -> ServerStream:
    """Receiver half of a streaming invoke: decode the anchor, build the
    handler's ArgView, call it, and wrap the returned iterable."""
    heap = ctx.heap()
    if flags & F_BYVAL:
        reader = ctx   # fallback route: reads fault pages across the link
    else:
        reader = _reader_for(ctx)
    (_head, gen_tag, _consumed, args_addr, window, _pad) = _ANCHOR.unpack(
        bytes(reader.read(arg, _ANCHOR.size)))
    if flags & F_BYVAL:
        raw = _read_blob(reader, args_addr, heap.page_size)
        view = ArgView.python(serial.decode(raw))
    else:
        view = ArgView.graph(reader, (C.T_VEC, args_addr))
    try:
        result = fn(ctx, view)
    except InvalidPointer as e:
        if ctx.sandbox is not None:
            raise SandboxViolation(str(e)) from e
        raise
    return ServerStream(ctx, iter(result), arg, gen_tag, window,
                        byval=bool(flags & F_BYVAL))


class RpcStream:
    """Client half of a streaming RPC on the CXL ring — an iterator that
    yields chunks **as the server publishes them** (time-to-first-token,
    not time-to-last).

    A per-``next`` ``timeout`` raises ``ChannelError`` and leaves the
    stream consumable (retry the wait); a lapsed stream *deadline* is
    terminal and hands the slot to the reaper. ``close()`` cancels: the
    sentinel store makes the server abort the generator at its next pump
    and the slot is reaped once that completion lands.
    """

    __slots__ = ("conn", "fn_id", "token", "_scope", "_pooled", "_sealed",
                 "_gen", "_timeout", "_deadline_us", "_pump", "_words",
                 "_watch", "_consumed_addr", "_prev", "_seq", "_state",
                 "_exc", "_scope_released")

    def __init__(self, conn, fn_id: int, token: Tuple[int, int],
                 anchor: int, scope: Scope, pooled: bool, sealed: bool,
                 gen_tag: int, timeout: float, deadline_us: int,
                 pump=None):
        self.conn = conn
        self.fn_id = fn_id
        self.token = token
        self._scope = scope
        self._pooled = pooled
        self._sealed = sealed
        self._gen = gen_tag
        self._timeout = timeout
        self._deadline_us = deadline_us
        self._pump = pump
        heap = conn.heap
        self._words = heap.buf.data.cast("Q")
        self._watch = gaddr.linear(anchor, heap.page_size) // 8
        self._consumed_addr = gaddr.add(anchor, _ANCHOR_CONSUMED_OFF,
                                        heap.page_size)
        tr = heap._tracer
        if tr is not None:
            # the anchor page carries the head/consumed watch words —
            # racy-by-design sync fabric, like the descriptor ring
            tr.sync_pages(heap,
                          gaddr.linear(anchor, heap.page_size)
                          // heap.page_size, 1)
        self._prev = 0   # last consumed chunk (recycled with a lag of one)
        self._seq = 0
        self._state = _PENDING
        self._exc: Optional[BaseException] = None
        self._scope_released = False

    def __iter__(self) -> "RpcStream":
        return self

    def __next__(self):
        return self.next()

    def next(self, timeout: Optional[float] = None):
        """The next chunk value; raises ``StopIteration`` at stream end,
        the RPC's mapped error on failure."""
        if self._state == _DONE:
            raise StopIteration
        if self._state != _PENDING:
            raise self._exc
        conn = self.conn
        ring = conn.ring
        slot = self.token[0]
        words = self._words
        policy = conn.wait_policy
        deadline = time.monotonic() + \
            (self._timeout if timeout is None else timeout)
        # a fixed-cadence policy asks for polite polling: skip the bare
        # GIL-yield prelude entirely — N streaming consumers spinning
        # sleep(0) between chunks would starve the serving thread's
        # dispatch path of the interpreter lock
        spins = 0 if policy.fixed is not None else 256
        while True:
            if conn.closed:
                # checked BEFORE touching the chain: close() freed the
                # chunk pages, so a stale watch word must not be chased
                self._fail_cleanup(ChannelError(
                    "connection closed with the stream in flight"))
                raise self._exc
            addr = words[self._watch]
            if addr:
                return self._consume_chunk(int(addr))
            if ring.state_of(slot) >= R_DONE:
                return self._settle_no_chunk()
            if self._deadline_us and _now_us() > self._deadline_us:
                self._lapse()
            if time.monotonic() > deadline:
                raise WaitTimeout("stream chunk timed out")
            if self._pump is not None:
                self._pump()   # inline mode: this thread IS the server
                continue
            if spins:
                spins -= 1
                time.sleep(0)
                continue
            time.sleep(policy.delay_s())

    # -- chunk consumption -----------------------------------------------
    def _consume_chunk(self, addr: int):
        conn = self.conn
        heap = conn.heap
        tr = heap._tracer
        if tr is not None:
            tr.sync_acquire(("chk", tr._space(heap), addr))
        try:
            (_nxt, cgen, seq, cflags, aux, vpayload) = _CHUNK.unpack(
                bytes(heap.read(addr, _CHUNK.size)))
        except InvalidPointer:
            if conn.closed:   # close() raced the read: chain pages gone
                self._fail_cleanup(ChannelError(
                    "connection closed with the stream in flight"))
                raise self._exc from None
            raise
        if cgen != self._gen:
            self._fail_cleanup(ChannelError(
                f"stale stream chunk: generation {cgen} != {self._gen}"))
            raise self._exc
        if cflags == CH_VALUE:
            if seq != self._seq:
                self._fail_cleanup(ChannelError(
                    f"stream chunk out of order: {seq} != {self._seq}"))
                raise self._exc
            value = C.to_python(heap, (aux, vpayload))
            self._seq += 1
            # open the server's bounded window (runtime metadata — a
            # daemon store, legal even while the anchor scope is sealed)
            if tr is not None:
                tr.sync_release(("cons", tr._space(heap),
                                 self._consumed_addr))
            heap.write(self._consumed_addr, _U32.pack(self._seq))
            if self._prev:
                # recycle lag of one: a chunk scope is reusable only once
                # its ``next`` word has been read
                _recycle_chunk(conn, self._prev)
            self._prev = addr
            self._watch = gaddr.linear(addr, heap.page_size) // 8
            return value
        if cflags == CH_END:
            self._settle(addr, None)
            if self._state == _FAILED:
                raise self._exc
            raise StopIteration
        # CH_ERR: aux carries the status, vpayload the retry-after hint
        self._settle(addr, aux, vpayload)
        raise self._exc

    def _settle(self, last_addr: int, status: Optional[int],
                val: int = 0) -> None:
        """Consume the completed ring slot (releasing the seal) and
        recycle the tail of the chain."""
        conn = self.conn
        exc: Optional[BaseException] = None
        try:
            conn.wait(self.token, sealed=self._sealed,
                      timeout=self._timeout)
        except BaseException as e:
            exc = e
        if self._prev:
            _recycle_chunk(conn, self._prev)
            self._prev = 0
        _recycle_chunk(conn, last_addr)
        self._release_scope_once()
        if exc is None and status is not None:
            if status == E_DEADLINE:
                exc = DeadlineExceeded("RPC deadline lapsed")
            elif status == E_OVERLOAD:
                exc = Overloaded("server shed the stream (E_OVERLOAD)",
                                 retry_after_s=val / 1e6)
            else:
                exc = RpcError(status)
        if exc is not None:
            self._state = _FAILED
            self._exc = exc
        else:
            self._state = _DONE

    def _settle_no_chunk(self):
        # the slot completed with no chunk pending: either this lost a
        # race with the final publish (re-check), or a non-streaming
        # handler answered with a single boxed reply
        addr = self._words[self._watch]
        if addr:
            return self._consume_chunk(int(addr))
        conn = self.conn
        try:
            ret = conn.wait(self.token, sealed=self._sealed,
                            timeout=self._timeout)
        except BaseException as e:
            self._fail_cleanup(e)
            raise
        _recycle_reply(conn, ret)
        self._fail_cleanup(ChannelError(
            "handler completed without streaming (declare the method "
            "with @method(streaming=True))"))
        raise self._exc

    def _lapse(self) -> None:
        """The stream *deadline* lapsed mid-wait: terminal — hand the
        slot to the reaper (the server's own deadline check completes
        it) and fail the iterator."""
        conn = self.conn
        exc = DeadlineExceeded("stream deadline lapsed")
        pending = conn._pending_async.get(self.token[0])
        if pending is not None:
            pending.cleanup = self._release_scope_once
            conn._abandon(self.token, pending)
        else:
            self._release_scope_once()
        if self._prev:
            _recycle_chunk(conn, self._prev)
            self._prev = 0
        self._state = _FAILED
        self._exc = exc
        raise exc

    # -- cancellation / hygiene ------------------------------------------
    def close(self) -> None:
        """Abandon the stream (best-effort cancel): the sentinel store
        aborts the server generator at its next pump; the ring slot is
        reaped when that completion lands."""
        if self._state != _PENDING:
            return
        conn = self.conn
        if not conn.closed:
            try:
                conn.heap.write(self._consumed_addr,
                                _U32.pack(_STREAM_CANCEL))
            except InvalidPointer:
                pass
            pending = conn._pending_async.get(self.token[0])
            if pending is not None:
                pending.cleanup = self._release_scope_once
                conn._abandon(self.token, pending)
        if self._prev:
            _recycle_chunk(conn, self._prev)
            self._prev = 0
        self._state = _FAILED
        self._exc = ChannelError("stream cancelled")

    def _fail_cleanup(self, exc: BaseException) -> None:
        if self._prev:
            _recycle_chunk(self.conn, self._prev)
            self._prev = 0
        self._release_scope_once()
        self._state = _FAILED
        self._exc = exc

    def _release_scope_once(self) -> None:
        if self._scope_released:
            return
        self._scope_released = True
        scope = self._scope
        _pool_recycle(self.conn, scope, self._pooled)


def _marshal_stream(conn: Connection, args: Tuple, gen_tag: int,
                    window: int, force_copy: bool):
    """(anchor, scope, pooled) — the stream anchor and the marshalled
    argument tuple, together in one (pooled when possible) scope."""
    pid = conn.client_pid

    def _fill(scope: Scope) -> int:
        anchor = scope.alloc(_ANCHOR.size)
        root = marshal_args(scope, args, pid, force_copy)
        conn.heap.write(anchor, _ANCHOR.pack(0, gen_tag, 0, root,
                                             window, 0), pid=pid)
        return anchor

    return _fill_pooled(conn, pid, _fill)


def invoke_stream_cxl(conn: Connection, fn_id: int, args: Tuple,
                      sealed: bool = False, sandboxed: bool = False,
                      deadline: Optional[float] = None,
                      timeout: float = 10.0,
                      window: int = DEFAULT_STREAM_WINDOW,
                      inline: bool = False) -> RpcStream:
    """Streaming typed invoke on the shared-memory ring: marshal (or
    pointer-pass) the arguments once, post one descriptor, and consume
    the server's reply chain chunk by chunk as it grows."""
    deadline_us = _deadline_word(deadline)
    conn._stream_gen += 1
    gen_tag = conn._stream_gen
    force_copy = sandboxed or sealed

    if len(args) == 1 and isinstance(args[0], GraphRef):
        g = args[0]
        if g.scope is not None and g.scope.heap is conn.heap and \
                not force_copy:
            # steady-state hot path: anchor-only scope, args by pointer
            pool = _marshal_pool(conn)
            scope = pool.pop()
            try:
                anchor = scope.alloc(_ANCHOR.size)
                conn.heap.write(anchor, _ANCHOR.pack(
                    0, gen_tag, 0, g.root, window, 0),
                    pid=conn.client_pid)
            except BaseException:
                pool.push(scope)
                raise
            return _post_stream(conn, fn_id, anchor, scope, True, sealed,
                                sandboxed, deadline_us, timeout, gen_tag,
                                inline)
        if g.scope is None or g.scope.heap is not conn.heap:
            args = tuple(g.to_python())
        # same-heap ref under seal/sandbox: the generic path deep-copies

    anchor, scope, pooled = _marshal_stream(conn, args, gen_tag, window,
                                            force_copy)
    return _post_stream(conn, fn_id, anchor, scope, pooled, sealed,
                        sandboxed, deadline_us, timeout, gen_tag, inline)


def _post_stream(conn, fn_id, anchor, scope, pooled, sealed, sandboxed,
                 deadline_us, timeout, gen_tag, inline) -> RpcStream:
    try:
        token = conn.call_async(fn_id, anchor, scope=scope, sealed=sealed,
                                sandboxed=sandboxed,
                                flags_extra=F_TYPED | F_STREAM,
                                deadline_us=deadline_us)
    except BaseException:
        _pool_recycle(conn, scope, pooled)
        raise
    conn.n_invokes += 1
    conn.marshal_bytes += scope.used_bytes()
    stream = RpcStream(conn, fn_id, token, anchor, scope, pooled, sealed,
                       gen_tag, timeout, deadline_us)
    conn._track_async(token, sealed=sealed, typed=True,
                      cleanup=stream._release_scope_once)
    if inline:
        # the two-core analogue for single-threaded setups: process the
        # descriptor now and let the consuming thread pump the stream
        # (same contract — and caveats — as call_inline)
        conn.channel._process(conn, token[0])
        conn.ring.head += 1
        stream._pump = conn.channel.pump_streams
    return stream


class FallbackRpcStream:
    """Client half of a streaming RPC over the software-coherent link.

    Pull-driven: when the local chunk queue runs dry, one *staged chunk
    flight* crosses the wire — the server advances the generator up to
    ``window`` chunks and every chunk page migrates back in ONE bulk
    transfer (the cMPI amortization applied to the reply chain), so the
    link latency is paid per flight, not per token.
    """

    __slots__ = ("conn", "fn_id", "slot", "window", "_scope", "_sealed",
                 "_seal_idx", "_gen", "_deadline_us", "_timeout", "_srv",
                 "_pending", "_prev", "_seq", "_state", "_exc",
                 "_scope_released")

    def __init__(self, conn, fn_id: int, slot: int, scope: Scope,
                 sealed: bool, seal_idx: int, gen_tag: int, window: int,
                 deadline_us: int, timeout: float):
        self.conn = conn
        self.fn_id = fn_id
        self.slot = slot
        self.window = window
        self._scope = scope
        self._sealed = sealed
        self._seal_idx = seal_idx
        self._gen = gen_tag
        self._deadline_us = deadline_us
        self._timeout = timeout
        self._srv: Optional[ServerStream] = None
        self._pending: List[int] = []   # migrated, not yet consumed
        self._prev = 0
        self._seq = 0
        self._state = _PENDING
        self._exc: Optional[BaseException] = None
        self._scope_released = False

    def __iter__(self) -> "FallbackRpcStream":
        return self

    def __next__(self):
        return self.next()

    def next(self, timeout: Optional[float] = None):
        if self._state == _DONE:
            raise StopIteration
        if self._state != _PENDING:
            raise self._exc
        conn = self.conn
        if conn.closed:
            self._teardown(ChannelError(
                "connection closed with the stream in flight"))
            raise self._exc
        if not self._pending:
            if self._srv is None or self._srv.done:
                return self._settle_slot()
            self._pending.extend(conn.pump_stream(self._srv, self.window))
            if not self._pending:
                return self._settle_slot()
        return self._consume_chunk(self._pending.pop(0))

    # -- chunk consumption -----------------------------------------------
    def _consume_chunk(self, addr: int):
        conn = self.conn
        node = conn.client
        (_nxt, cgen, seq, cflags, aux, vpayload) = _CHUNK.unpack(
            bytes(node.read(addr, _CHUNK.size)))
        if cgen != self._gen:
            self._teardown(ChannelError(
                f"stale stream chunk: generation {cgen} != {self._gen}"))
            raise self._exc
        if cflags == CH_VALUE:
            if seq != self._seq:
                self._teardown(ChannelError(
                    f"stream chunk out of order: {seq} != {self._seq}"))
                raise self._exc
            value = serial.decode(bytes(node.read(vpayload, aux)))
            self._seq += 1
            if self._prev:
                _recycle_chunk(conn, self._prev)
            self._prev = addr
            return value
        self._settle(addr, None if cflags == CH_END else aux, vpayload)
        if self._state == _FAILED:
            raise self._exc
        raise StopIteration

    def _settle(self, last_addr: int, status: Optional[int],
                val: int = 0) -> None:
        conn = self.conn
        conn.link.send_msg(CHUNK_HDR_BYTES)   # completion descriptor
        tr = conn.client.heap._tracer
        if tr is not None:
            tr.sync_acquire(("rep", id(conn.ring), self.slot))
        _ret, _state, _status = conn.ring.consume(self.slot)
        self._release_seal_once()
        if self._prev:
            _recycle_chunk(conn, self._prev)
            self._prev = 0
        _recycle_chunk(conn, last_addr)
        self._release_scope_once()
        conn.n_calls += 1
        conn._drop_client_stream(self)
        if status is None:
            self._state = _DONE
            return
        self._state = _FAILED
        if status == E_DEADLINE:
            self._exc = DeadlineExceeded("RPC deadline lapsed")
        elif status == E_OVERLOAD:
            self._exc = Overloaded("server shed the stream (E_OVERLOAD)",
                                   retry_after_s=val / 1e6)
        else:
            self._exc = RpcError(status)

    def _settle_slot(self):
        """No chunks and no live server stream: the call failed before
        (or without) streaming — surface the recorded error."""
        conn = self.conn
        ring = conn.ring
        if ring.state_of(self.slot) < R_DONE:
            self._teardown(ChannelError("stream produced no chunks"))
            raise self._exc
        tr = conn.client.heap._tracer
        if tr is not None:
            tr.sync_acquire(("rep", id(ring), self.slot))
        ret, state, status = ring.consume(self.slot)
        exc = conn._flight_errors.pop(self.slot, None)
        self._release_seal_once()
        self._release_scope_once()
        conn._drop_client_stream(self)
        if state == R_DONE:
            _recycle_reply(conn, ret)
        if exc is None:
            if status == E_DEADLINE:
                exc = DeadlineExceeded("RPC deadline lapsed")
            elif status == E_OVERLOAD:
                exc = Overloaded("server shed the stream (E_OVERLOAD)")
            elif state == R_ERR:
                exc = RpcError(status)
            else:
                exc = ChannelError(
                    "handler completed without streaming (declare the "
                    "method with @method(streaming=True))")
        self._state = _FAILED
        self._exc = exc
        raise exc

    # -- cancellation / hygiene ------------------------------------------
    def close(self) -> None:
        """Abandon the stream: abort the server generator, consume the
        slot, and drain every client-held resource exactly once."""
        if self._state != _PENDING:
            return
        conn = self.conn
        if self._srv is not None and not self._srv.done:
            self._srv.abort()
            if conn.ring.state_of(self.slot) < R_DONE:
                conn.ring.complete(self.slot, 0, R_ERR, E_EXCEPTION)
        if conn.ring.state_of(self.slot) >= R_DONE:
            conn.ring.consume(self.slot)
        conn._flight_errors.pop(self.slot, None)
        self._teardown(ChannelError("stream cancelled"))

    def _fail_on_close(self) -> None:
        """Connection-close hook: fail the waiter with ChannelError and
        drain the argument scope exactly once (chunk scopes die with the
        connection's reply/chain registries)."""
        if self._srv is not None:
            self._srv.abort()
        if self._state == _PENDING:
            self._state = _FAILED
            self._exc = ChannelError(
                "connection closed with the stream in flight")
        self._release_scope_once()

    def _teardown(self, exc: BaseException) -> None:
        conn = self.conn
        self._release_seal_once()
        for addr in (*([self._prev] if self._prev else ()),
                     *self._pending):
            _recycle_chunk(conn, addr)
        self._prev = 0
        self._pending.clear()
        self._release_scope_once()
        conn._drop_client_stream(self)
        self._state = _FAILED
        self._exc = exc

    def _release_seal_once(self) -> None:
        if self._sealed:
            self._sealed = False
            try:
                self.conn.seals.release(self._seal_idx,
                                        holder=self.conn.client_pid)
            except SealViolation:
                pass

    def _release_scope_once(self) -> None:
        if not self._scope_released:
            self._scope_released = True
            if self._scope.live:
                self._scope.destroy()


def invoke_stream_fallback(conn, fn_id: int, args: Tuple,
                           sealed: bool = False, sandboxed: bool = False,
                           deadline: Optional[float] = None,
                           timeout: float = 10.0,
                           window: int = STREAM_FLIGHT_CHUNKS,
                           **_ignored) -> FallbackRpcStream:
    """Streaming typed invoke over the link: by-value args cross once,
    then the reply chain comes back in staged flights of up to ``window``
    chunks per wire flush."""
    payload = serial.encode(_args_to_plain(args))
    nbytes = _ANCHOR.size + _BLOB_HDR.size + len(payload)
    scope = conn.create_scope(nbytes)
    conn._stream_gen += 1
    gen_tag = conn._stream_gen
    deadline_us = _deadline_word(deadline)
    try:
        anchor = scope.alloc(_ANCHOR.size)
        a = scope.alloc(_BLOB_HDR.size + len(payload))
        conn.client.write(a, _BLOB_HDR.pack(len(payload)) + payload,
                          pid=conn.client_pid)
        conn.client.write(anchor,
                          _ANCHOR.pack(0, gen_tag, 0, a, window, 0),
                          pid=conn.client_pid)
        slot, seal_idx = conn._post(fn_id, anchor, scope, sealed,
                                    sandboxed,
                                    F_TYPED | F_BYVAL | F_STREAM,
                                    deadline_us)
    except BaseException:
        scope.destroy()
        raise
    conn.n_invokes += 1
    conn.marshal_bytes += len(payload)
    stream = FallbackRpcStream(conn, fn_id, slot, scope, sealed, seal_idx,
                               gen_tag, window, deadline_us, timeout)
    conn.start_stream(stream)
    return stream


# ---------------------------------------------------------------------------
# invoke — CXL route (pointer passing)
# ---------------------------------------------------------------------------
def invoke_cxl(conn: Connection, fn_id: int, args: Tuple,
               sealed: bool = False, sandboxed: bool = False,
               batch_release: bool = False, timeout: float = 10.0,
               inline: bool = False, spin_sleep_us: float = 0.0,
               deadline: Optional[float] = None):
    """Typed invoke on the shared-memory ring: materialize-once, pass a
    pointer, decode the marshalled reply."""
    caller = conn.call_inline if inline else conn.call
    kw: Dict[str, Any] = {} if inline else \
        {"timeout": timeout, "spin_sleep_us": spin_sleep_us}
    if deadline is not None:
        kw["deadline_us"] = _deadline_word(deadline)

    # steady-state hot path: a single pre-built graph in this heap is
    # passed by pointer — zero marshalling work per call
    if len(args) == 1 and isinstance(args[0], GraphRef):
        g = args[0]
        if g.scope is not None and g.scope.heap is conn.heap:
            conn.n_invokes += 1
            ret = caller(fn_id, g.root, scope=g.scope, sealed=sealed,
                         sandboxed=sandboxed, batch_release=batch_release,
                         flags_extra=F_TYPED, **kw)
            return _read_reply_graph(conn, ret)
        # foreign-heap / plain ref: deep-copy the tuple across (§5.6)
        args = tuple(g.to_python())

    pid = conn.client_pid
    # sandboxed: the sandbox covers only the call scope, so embedded
    # graphs must be copied into it; sealed: the seal likewise protects
    # only the call scope — a pointer-embedded graph would stay sender-
    # writable mid-flight, the exact §4.5 TOCTOU sealing prevents
    root, scope, pooled = _pooled_marshal(conn, args, pid,
                                          force_copy=sandboxed or sealed)
    conn.n_invokes += 1
    conn.marshal_bytes += scope.used_bytes()
    try:
        ret = caller(fn_id, root, scope=scope, sealed=sealed,
                     sandboxed=sandboxed, batch_release=batch_release,
                     flags_extra=F_TYPED, **kw)
    finally:
        if pooled and sealed and batch_release:
            # pages stay write-protected until the batch flush (§5.3)
            _pool_recycle(conn, scope, True, seal_idx=conn.last_seal_idx)
        else:
            _pool_recycle(conn, scope, pooled)
    return _read_reply_graph(conn, ret)


# ---------------------------------------------------------------------------
# invoke — serialized routes (fallback transport + Fig. 11 baseline)
# ---------------------------------------------------------------------------
def _to_plain(v):
    """§5.6 copy semantics for a graph crossing a coherence boundary:
    the structural traversal materializes it (the ``deep_copy`` read
    half) and the result travels by value."""
    if isinstance(v, GraphRef):
        return v.to_python()
    return v


def _args_to_plain(args: Tuple) -> list:
    if len(args) == 1 and isinstance(args[0], GraphRef):
        return args[0].to_python()   # the ref IS the argument tuple
    return [_to_plain(v) for v in args]


def invoke_fallback(conn, fn_id: int, args: Tuple, sealed: bool = False,
                    sandboxed: bool = False, batch_release: bool = False,
                    timeout: float = 10.0, inline: bool = False,
                    deadline: Optional[float] = None, **_ignored):
    """Typed invoke over the software-coherent link: same surface, but
    the arguments are serial-encoded and travel by value (one blob copy
    over the wire instead of N page ping-pongs chasing pointers)."""
    payload = serial.encode(_args_to_plain(args))
    nbytes = _BLOB_HDR.size + len(payload)
    scope = conn.create_scope(nbytes)
    conn.n_invokes += 1
    conn.marshal_bytes += len(payload)
    try:
        a = scope.alloc(nbytes)
        conn.client.write(a, _BLOB_HDR.pack(len(payload)) + payload,
                          pid=conn.client_pid)
        ret = conn.call(fn_id, a, scope=scope, sealed=sealed,
                        sandboxed=sandboxed, batch_release=batch_release,
                        flags_extra=F_TYPED | F_BYVAL,
                        deadline_us=_deadline_word(deadline))
        # the reply blob faults its pages back over the link — the copy
        raw = _read_blob(conn.client, ret, conn.client.page_size)
        _recycle_reply(conn, ret)
        return serial.decode(raw)
    finally:
        scope.destroy()


class FallbackRpcFuture:
    """A pipelined invoke on the software-coherent link. Same surface as
    ``RpcFuture``; underneath, the descriptor+payload are *staged* and
    the whole flight crosses the wire on the first settlement (or an
    explicit ``conn.flush()``) — N staged invokes share one link-latency
    round trip instead of paying it N times."""

    __slots__ = ("conn", "fn_id", "slot", "_scope", "_sealed", "_seal_idx",
                 "_deadline_us", "_state", "_value", "_exc")

    def __init__(self, conn, fn_id: int, slot: int, scope: Scope,
                 sealed: bool, seal_idx: int, deadline_us: int):
        self.conn = conn
        self.fn_id = fn_id
        self.slot = slot
        self._scope = scope
        self._sealed = sealed
        self._seal_idx = seal_idx
        self._deadline_us = deadline_us
        self._state = _PENDING
        self._value = None
        self._exc: Optional[BaseException] = None

    def done(self) -> bool:
        if self._state != _PENDING:
            return True
        return not self.conn.in_flight(self.slot) and \
            self.conn.ring.state_of(self.slot) >= R_DONE

    def _kick(self) -> None:
        self.conn.flush()

    def cancel(self) -> bool:
        if self._state != _PENDING:
            return False
        self._state = _CANCELLED
        self._exc = ChannelError("future cancelled")
        self.conn.abandon_flight_entry(self.slot, self._scope,
                                       self._sealed, self._seal_idx)
        return True

    def result(self, timeout: Optional[float] = None):
        if self._state == _DONE:
            return self._value
        if self._state != _PENDING:
            raise self._exc
        conn = self.conn
        if conn.closed:
            self._state = _FAILED
            self._exc = ChannelError(
                "connection closed with the RPC in flight")
            raise self._exc
        if conn.in_flight(self.slot):
            conn.flush()
        tr = conn.client.heap._tracer
        if tr is not None:
            tr.sync_acquire(("rep", id(conn.ring), self.slot))
        ret, state, status = conn.ring.consume(self.slot)
        if self._sealed and not conn._consume_window_release(self._seal_idx):
            # the window flush did not cover this seal (error path, or
            # window batching disabled): fall back to a per-future release
            conn.seals.release(self._seal_idx, holder=conn.client_pid)
        try:
            exc = conn._flight_errors.pop(self.slot, None)
            if exc is not None:
                raise exc
            if state == R_ERR:
                if status == E_DEADLINE:
                    raise DeadlineExceeded("RPC deadline lapsed")
                if status == E_OVERLOAD:
                    raise Overloaded(
                        "server shed the request (E_OVERLOAD)",
                        retry_after_s=ret * 1e-6)
                raise RpcError(status)
            # the reply pages were bulk-migrated back by the flush; this
            # read is local (a straggler still faults correctly)
            raw = _read_blob(conn.client, ret, conn.client.page_size)
            _recycle_reply(conn, ret)
            self._value = serial.decode(raw)
        except BaseException as e:
            self._state = _FAILED
            self._exc = e
            raise
        finally:
            if self._scope.live:
                self._scope.destroy()
            conn.n_calls += 1
        self._state = _DONE
        return self._value


def invoke_async_fallback(conn, fn_id: int, args: Tuple,
                          sealed: bool = False, sandboxed: bool = False,
                          deadline: Optional[float] = None,
                          timeout: float = 10.0,
                          **_ignored) -> FallbackRpcFuture:
    """Stage a typed by-value invoke for the next pipelined flight (§5.6
    copy semantics, cMPI-style latency amortization)."""
    payload = serial.encode(_args_to_plain(args))
    nbytes = _BLOB_HDR.size + len(payload)
    scope = conn.create_scope(nbytes)
    deadline_us = _deadline_word(deadline)
    try:
        a = scope.alloc(nbytes)
        conn.client.write(a, _BLOB_HDR.pack(len(payload)) + payload,
                          pid=conn.client_pid)
        slot = conn.post_async(fn_id, a, scope, sealed=sealed,
                               sandboxed=sandboxed,
                               flags_extra=F_TYPED | F_BYVAL,
                               deadline_us=deadline_us)
    except BaseException:
        scope.destroy()
        raise
    conn.n_invokes += 1
    conn.marshal_bytes += len(payload)
    seal_idx = conn.ring.seal_idx[slot]
    return FallbackRpcFuture(conn, fn_id, slot, scope, sealed,
                             int(seal_idx), deadline_us)


def invoke_serialized(conn: Connection, fn_id: int, args: Tuple,
                      sealed: bool = False, sandboxed: bool = False,
                      timeout: float = 10.0, inline: bool = False,
                      spin_sleep_us: float = 0.0,
                      deadline: Optional[float] = None):
    """The serializing baseline on the SAME CXL descriptor ring: encode,
    copy the blob through shared memory, full decode on the receiver,
    encode+decode the reply. Everything Fig. 11 shows RPCool avoiding,
    with the ring machinery held identical."""
    caller = conn.call_inline if inline else conn.call
    kw: Dict[str, Any] = {} if inline else \
        {"timeout": timeout, "spin_sleep_us": spin_sleep_us}
    if deadline is not None:
        kw["deadline_us"] = _deadline_word(deadline)
    payload = serial.encode(_args_to_plain(args))
    nbytes = _BLOB_HDR.size + len(payload)

    pid = conn.client_pid
    pooled = nbytes <= MARSHAL_SCOPE_PAGES * conn.heap.page_size
    if pooled:
        scope = _marshal_pool(conn).pop()
    else:
        scope = create_scope(conn.heap, nbytes, owner=pid)
    try:
        a = scope.alloc(nbytes)
        conn.heap.write(a, _BLOB_HDR.pack(len(payload)) + payload, pid=pid)
        ret = caller(fn_id, a, scope=scope, sealed=sealed,
                     sandboxed=sandboxed, flags_extra=F_TYPED | F_BYVAL,
                     **kw)
    finally:
        _pool_recycle(conn, scope, pooled)
    raw = _read_blob(conn.heap, ret, conn.heap.page_size)
    _recycle_reply(conn, ret)
    return serial.decode(raw)
