"""Typed zero-copy argument marshalling — the unified data plane.

The paper's headline claim is serialization *avoidance* (§4.1, Fig. 11):
an RPC passes a pointer to a pointer-rich structure living in shared
memory; seals and sandboxes restore the isolation that copying used to
provide. This module is the layer that makes that the *default calling
convention* instead of a bytes-in/int-out one:

* ``conn.invoke(fn_id, *values)`` — arguments (arbitrary nested Python
  values, or pre-built ``GraphRef`` container graphs) are materialized
  ONCE as a ``containers`` graph inside a pooled scope, optionally
  sealed, and passed as a single GlobalAddr. Zero serialization.
* On a ``FallbackConnection`` the *same surface* transparently routes by
  value: ``serial.encode`` → one blob copy over the link → decode (the
  §5.6 ``copy_from`` semantics). ``RoutedConnection`` therefore picks
  pointer-passing vs copy per route with no caller change.
* Handler side, ``Channel.add_typed`` handlers receive an ``ArgView``:
  a lazy view that chases pointers on demand. Under a sandboxed request
  every dereference goes through a bounds-checked reader (the §4.3
  wild-pointer attack path surfaces as ``SandboxViolation`` → E_SANDBOX,
  never as server memory disclosure); replies are marshalled back into a
  recycled reply scope the same way.
* ``invoke_serialized`` runs the gRPC-analogue baseline over the SAME
  descriptor ring, so benchmarks/marshal.py measures exactly the
  serialize+copy+deserialize delta of Fig. 11 / Table 1a.

Reply protocol: the ring's 64-bit ``ret`` word carries the GlobalAddr of
either a 16-byte boxed Value (pointer route) or a ``[u32 len][bytes]``
blob (by-value route). Reply scopes are popped from a per-connection
freelist by the server and pushed back by the client after decoding —
the steady state allocates nothing.
"""

from __future__ import annotations

import struct
from typing import Any, Dict, Iterator, List, Optional, Tuple

import time

from . import addr as gaddr
from . import containers as C
from . import serial
from .channel import Connection, E_DEADLINE, F_BYVAL, F_SANDBOXED, \
    F_SEALED, F_TYPED, R_DONE, R_ERR, RpcError, _now_us
from .errors import AllocationError, ChannelError, DeadlineExceeded, \
    InvalidPointer, SandboxViolation
from .scope import Scope, ScopePool, create_scope

# Pooled argument scopes: 4 pages (16 KiB with the default page size)
# covers typical pointer-rich documents; bigger argument sets fall back
# to a dedicated right-sized scope.
MARSHAL_SCOPE_PAGES = 4
REPLY_SCOPE_PAGES = 1
_REPLY_FREELIST_MAX = 4
# replies the client never consumed (timeouts, decode errors) are capped:
# past this many live reply scopes the oldest is reclaimed — invoke is
# synchronous, so anything that old is garbage, not in flight
_REPLY_LIVE_MAX = 64

_BOX = struct.Struct("<IIQ")      # boxed reply Value (= containers layout)
_BLOB_HDR = struct.Struct("<I")   # length prefix of a by-value payload

_MISSING = object()


class GraphRef:
    """A pre-built argument-tuple graph resident in a connection's heap.

    ``build_graph(conn, *values)`` materializes the argument tuple once;
    passing the ref to ``invoke`` afterwards is pure pointer passing —
    zero per-call marshalling, the paper's steady-state hot path. On a
    copy-route connection (no shared heap) the ref simply retains the
    plain values and each invoke serializes them, keeping the surface
    identical across routes.
    """

    __slots__ = ("scope", "value", "plain")

    def __init__(self, scope: Optional[Scope], value: Optional[C.Value],
                 plain: Optional[list] = None):
        self.scope = scope
        self.value = value
        self.plain = plain

    @property
    def root(self) -> int:
        return self.value[1]

    @property
    def heap(self):
        return None if self.scope is None else self.scope.heap

    def to_python(self) -> list:
        """The argument tuple as plain values (§5.6 copy-out half)."""
        if self.scope is None:
            return list(self.plain)
        return C.to_python(self.scope.heap, self.value)

    def destroy(self) -> None:
        if self.scope is not None and self.scope.live:
            self.scope.destroy()


class ArgView:
    """Uniform lazy view over typed RPC arguments.

    Graph-backed (pointer route): every access walks the ``containers``
    graph through a reader — the connection heap when trusted, a
    bounds-checked sandbox reader when the request is sandboxed. Nothing
    is deserialized; the handler touches only what it dereferences.

    Python-backed (by-value route): wraps the already-decoded object so
    the same handler code serves both routes.

    Scalars (ints, floats, strings, None) unwrap to Python values on
    access; Vec/Map nodes come back as nested ``ArgView``s.
    """

    __slots__ = ("_reader", "_val", "_py")

    def __init__(self, reader, val: Optional[C.Value], py=_MISSING):
        self._reader = reader
        self._val = val
        self._py = py

    # -- constructors ----------------------------------------------------
    @classmethod
    def graph(cls, reader, value: C.Value) -> "ArgView":
        return cls(reader, value)

    @classmethod
    def python(cls, obj) -> "ArgView":
        return cls(None, None, obj)

    # -- wrapping --------------------------------------------------------
    def _wrap(self, v: C.Value):
        tag, p = v
        if tag == C.T_NULL:
            return None
        if tag == C.T_I64:
            return p - (1 << 64) if p >= (1 << 63) else p
        if tag == C.T_F64:
            return C._unpack_f64(p)
        if tag == C.T_STR:
            return C.read_str(self._reader, p)
        if tag == C.T_BYTES:
            return C.read_bytes(self._reader, p)
        return ArgView(self._reader, v)

    @staticmethod
    def _wrap_py(obj):
        if isinstance(obj, (dict, list, tuple)):
            return ArgView.python(obj)
        return obj

    # -- the access surface ----------------------------------------------
    def __len__(self) -> int:
        if self._reader is None:
            return len(self._py)
        tag, p = self._val
        if tag == C.T_VEC:
            return C.vec_len(self._reader, p)
        if tag == C.T_MAP:
            return C.map_len(self._reader, p)
        raise InvalidPointer(f"len() of non-container value tag {tag}")

    def __getitem__(self, key):
        if self._reader is None:
            return self._wrap_py(self._py[key])
        tag, p = self._val
        if isinstance(key, str):
            if tag != C.T_MAP:
                raise InvalidPointer(f"string index into value tag {tag}")
            v = C.map_get(self._reader, p, key)
            if v is None:
                raise KeyError(key)
            return self._wrap(v)
        if tag != C.T_VEC:
            raise InvalidPointer(f"integer index into value tag {tag}")
        n = C.vec_len(self._reader, p)
        if key < 0:
            key += n
        return self._wrap(C.vec_get(self._reader, p, key))

    def get(self, key: str, default=None):
        if self._reader is None:
            return self._wrap_py(self._py.get(key, default))
        tag, p = self._val
        if tag != C.T_MAP:
            raise InvalidPointer(f"get() on value tag {tag}")
        v = C.map_get(self._reader, p, key)
        return default if v is None else self._wrap(v)

    def keys(self) -> List[str]:
        if self._reader is None:
            return list(self._py.keys())
        tag, p = self._val
        if tag != C.T_MAP:
            raise InvalidPointer(f"keys() on value tag {tag}")
        return [k for k, _ in C.map_items(self._reader, p)]

    def __iter__(self) -> Iterator:
        if self._reader is None:
            if isinstance(self._py, dict):
                return iter(self._py.keys())
            return (self._wrap_py(v) for v in self._py)
        tag, p = self._val
        if tag == C.T_MAP:
            return iter(self.keys())
        if tag == C.T_VEC:
            return (self._wrap(C.vec_get(self._reader, p, i))
                    for i in range(C.vec_len(self._reader, p)))
        raise InvalidPointer(f"iteration over value tag {tag}")

    def __contains__(self, key: str) -> bool:
        if self._reader is None:
            if not isinstance(self._py, dict):
                raise InvalidPointer("`in` requires a map value")
            return key in self._py
        tag, p = self._val
        if tag != C.T_MAP:
            raise InvalidPointer(f"`in` on value tag {tag}")
        return C.map_get(self._reader, p, key) is not None

    def to_python(self):
        """Materialize the whole subtree (the explicit opt-in to a full
        deserialize — what the lazy surface otherwise avoids)."""
        if self._reader is None:
            obj = self._py
            if isinstance(obj, tuple):
                return list(obj)
            return obj
        return C.to_python(self._reader, self._val)


# ---------------------------------------------------------------------------
# argument marshalling (client side)
# ---------------------------------------------------------------------------
def _build_arg(scope: Scope, v, pid: int, force_copy: bool) -> C.Value:
    """One argument → Value in ``scope``.

    A ``GraphRef`` living in the same heap is pointer-embedded for free
    (the whole point); one in a foreign heap — or any graph under a
    sandboxed call, whose sandbox covers only the call scope — is
    ``deep_copy``'d into the scope (§5.6 ``copy_from``).
    """
    if isinstance(v, GraphRef):
        if v.scope is None:   # plain ref: rebuild its retained values
            return C.build_value(scope, v.plain, pid)
        if v.scope.heap is scope.heap and not force_copy:
            return v.value
        return C.deep_copy(v.scope.heap, scope, v.value, pid)
    return C.build_value(scope, v, pid)


def marshal_args(scope: Scope, args: Tuple, pid: int = 0,
                 force_copy: bool = False) -> int:
    """Materialize the argument tuple as a Vec graph; returns its root."""
    vals = [_build_arg(scope, v, pid, force_copy) for v in args]
    return C.build_vec(scope, vals, pid)[1]


def build_graph(conn, *values) -> GraphRef:
    """Materialize an argument tuple once in ``conn``'s heap.

    The returned ``GraphRef`` can be passed to ``invoke`` any number of
    times — each call is then pure pointer passing. Works on CXL and
    routed connections (``RoutedConnection.build_graph`` delegates here
    against the live target); a copy-route target gets a plain-value ref
    since there is no shared heap to materialize into."""
    heap = getattr(conn, "heap", None)
    if heap is None:  # FallbackConnection: the route copies either way
        return GraphRef(None, None, plain=[_to_plain(v) for v in values])
    pages = MARSHAL_SCOPE_PAGES
    while True:
        scope = conn.create_scope(pages * heap.page_size)
        try:
            root = marshal_args(scope, values, pid=conn.client_pid)
            return GraphRef(scope, (C.T_VEC, root))
        except AllocationError:
            scope.destroy()
            if pages > (1 << 16):
                raise
            pages *= 4
        except BaseException:
            scope.destroy()   # unsupported value etc. — no page leak
            raise


def _marshal_pool(conn: Connection) -> ScopePool:
    pool = conn._marshal_pool
    if pool is None or pool.scope_pages != MARSHAL_SCOPE_PAGES:
        pool = conn._marshal_pool = ScopePool(
            conn.heap, MARSHAL_SCOPE_PAGES, owner=conn.client_pid,
            seals=conn.seals)
    return pool


def _pooled_marshal(conn: Connection, args: Tuple, pid: int,
                    force_copy: bool) -> Tuple[int, Scope, bool]:
    """(root, scope, pooled?) — pooled fast path, dedicated on overflow."""
    pool = _marshal_pool(conn)
    scope = pool.pop()
    try:
        return marshal_args(scope, args, pid, force_copy), scope, True
    except AllocationError:
        pool.push(scope)
    except BaseException:
        pool.push(scope)      # bad value (TypeError, …) — no scope leak
        raise
    pages = MARSHAL_SCOPE_PAGES * 4
    while True:
        scope = create_scope(conn.heap, pages * conn.heap.page_size,
                             owner=pid)
        try:
            return marshal_args(scope, args, pid, force_copy), scope, False
        except AllocationError:
            scope.destroy()
            if pages > (1 << 16):
                raise
            pages *= 4
        except BaseException:
            scope.destroy()
            raise


# ---------------------------------------------------------------------------
# reply marshalling (server side) + decoding (client side)
# ---------------------------------------------------------------------------
def _reply_heap(conn):
    heap = getattr(conn, "heap", None)
    return heap if heap is not None else conn.client.heap


def _pop_reply_scope(conn, nbytes: int) -> Tuple[Scope, bool]:
    heap = _reply_heap(conn)
    if nbytes <= REPLY_SCOPE_PAGES * heap.page_size:
        free = conn._reply_free
        if free:
            s = free.pop()
            s.reset()
            return s, True
        return create_scope(heap, REPLY_SCOPE_PAGES * heap.page_size), True
    return create_scope(heap, nbytes), False


def _release_reply_scope(conn, scope: Scope) -> None:
    """The one push-or-destroy policy for reply scopes."""
    if scope.num_pages == REPLY_SCOPE_PAGES and \
            len(conn._reply_free) < _REPLY_FREELIST_MAX:
        conn._reply_free.append(scope)
    elif scope.live:
        scope.destroy()


def _track_reply(conn, addr: int, scope: Scope) -> None:
    live = conn._reply_live
    if len(live) >= _REPLY_LIVE_MAX:
        # a client that errored before decoding (timeout, link failure)
        # strands its reply scope here; reclaim the oldest so repeated
        # errors cannot pin the channel heap
        oldest = next(iter(live))
        _release_reply_scope(conn, live.pop(oldest))
    live[addr] = scope


def _recycle_reply(conn, addr: int) -> None:
    scope = conn._reply_live.pop(addr, None)
    if scope is not None:
        _release_reply_scope(conn, scope)


def _write_reply_graph(ctx, ret) -> int:
    """Marshal a handler's return value as a boxed Value + graph."""
    conn = ctx.conn
    scope, _pooled = _pop_reply_scope(conn, REPLY_SCOPE_PAGES)
    heap = _reply_heap(conn)
    nbytes = REPLY_SCOPE_PAGES * heap.page_size
    while True:
        try:
            val = C.build_value(scope, ret)
            box = scope.alloc(C.VALUE_SIZE)
            scope.heap.write(box, _BOX.pack(val[0], 0, val[1]))
            break
        except AllocationError:
            # big reply: retry in a geometrically larger dedicated scope
            # (serial length is NOT a bound — e.g. None is 1 B on the
            # wire but a 16 B containers Value)
            _release_reply_scope(conn, scope)
            nbytes *= 8
            if nbytes > heap.num_pages * heap.page_size:
                raise
            scope, _pooled = _pop_reply_scope(conn, nbytes)
    _track_reply(conn, box, scope)
    return box


def _read_reply_graph(conn, box: int):
    heap = conn.heap
    tag, _, payload = _BOX.unpack(bytes(heap.read(box, C.VALUE_SIZE)))
    out = C.to_python(heap, (tag, payload))
    _recycle_reply(conn, box)
    return out


def _write_reply_blob(ctx, raw: bytes) -> int:
    conn = ctx.conn
    scope, _pooled = _pop_reply_scope(conn, _BLOB_HDR.size + len(raw))
    a = scope.alloc(_BLOB_HDR.size + len(raw))
    # privileged runtime store — the reply lands outside the handler's
    # sandbox, like librpcool writing after SB_END
    ctx._daemon_write(a, _BLOB_HDR.pack(len(raw)) + raw)
    _track_reply(conn, a, scope)
    return a


def _read_blob(reader, a: int, psize: int) -> bytes:
    n = _BLOB_HDR.unpack(bytes(reader.read(a, _BLOB_HDR.size)))[0]
    return bytes(reader.read(gaddr.add(a, _BLOB_HDR.size, psize), n))


# ---------------------------------------------------------------------------
# the typed handler wrapper (receiver half)
# ---------------------------------------------------------------------------
def _reader_for(ctx):
    """The §4.4 contract: a sandboxed request chases pointers through a
    bounds-checked reader (one range check per dereference — the MMU
    fault check under the MPK cost model); a trusted request gets the
    raw-view reader over the whole heap (hardware loads cost nothing
    extra once the mapping exists). A fallback-route ctx reads through
    itself so page faults keep migrating pages."""
    sb = ctx.sandbox
    if sb is not None:
        return C.fast_reader_for_sandbox(sb)
    heap = ctx.heap()
    if getattr(ctx, "conn", None) is not None and \
            getattr(ctx.conn, "server", None) is not None:
        return ctx   # DSM node: reads must fault pages across the link
    return C.FastReader(heap)


def typed_handler(fn):
    """Wrap ``fn(ctx, args: ArgView) -> value`` as a raw ring handler.

    The wrapper dispatches on the descriptor flags, so ONE registration
    serves both routes: F_TYPED alone = pointer-passing (graph view),
    F_TYPED|F_BYVAL = serialized by-value (fallback route / baseline).
    """
    def wrapper(ctx, arg: int) -> int:
        flags = ctx.flags
        if not flags & F_TYPED:
            raise ChannelError(
                "typed handler called through the raw data path "
                "(use conn.invoke, not conn.call)")
        if flags & F_BYVAL:
            heap = ctx.heap()
            raw = _read_blob(ctx, arg, heap.page_size)
            view = ArgView.python(serial.decode(raw))   # full deserialize
            ret = fn(ctx, view)
            return _write_reply_blob(ctx, serial.encode(ret))
        view = ArgView.graph(_reader_for(ctx), (C.T_VEC, arg))
        try:
            ret = fn(ctx, view)
        except InvalidPointer as e:
            if ctx.sandbox is not None:
                # the §4.3 wild-pointer attack path: a bad pointer inside
                # a sandboxed request is a sandbox fault (→ E_SANDBOX
                # reply), never an exception class that leaks less intent
                raise SandboxViolation(str(e)) from e
            raise
        return _write_reply_graph(ctx, ret)

    wrapper.__wrapped__ = fn
    wrapper.typed = True
    return wrapper


# ---------------------------------------------------------------------------
# pipelined futures (invoke_async / gather)
# ---------------------------------------------------------------------------
_PENDING, _DONE, _FAILED, _CANCELLED = range(4)


def _deadline_word(deadline: Optional[float]) -> int:
    """Relative seconds of budget → the descriptor's absolute-µs word."""
    return 0 if deadline is None else _now_us() + int(deadline * 1e6)


class RpcFuture:
    """One in-flight typed RPC on a CXL ring connection.

    Many futures may be outstanding on one connection (the whole point of
    per-thread MPK permissions, §5.2) and they complete in whatever order
    the server drains slots; ``gather`` consumes them as they land. A
    future owns its marshal scope until settlement: ``result`` releases
    it back to the pool, ``cancel``/terminal errors release it exactly
    once, and a wait timeout leaves it alive (the server may still be
    reading the arguments mid-flight).
    """

    __slots__ = ("conn", "fn_id", "token", "_scope", "_pooled", "_sealed",
                 "_timeout", "_deadline_us", "_state", "_value", "_exc",
                 "_scope_released")

    def __init__(self, conn, fn_id: int, token: Tuple[int, int],
                 scope: Optional[Scope], pooled: bool, sealed: bool,
                 timeout: float, deadline_us: int):
        self.conn = conn
        self.fn_id = fn_id
        self.token = token
        self._scope = scope
        self._pooled = pooled
        self._sealed = sealed
        self._timeout = timeout
        self._deadline_us = deadline_us
        self._state = _PENDING
        self._value = None
        self._exc: Optional[BaseException] = None
        self._scope_released = scope is None

    # -- scope hygiene (the one-shot close()/reap cleanup hook) ----------
    def _release_scope_once(self) -> None:
        if self._scope_released:
            return
        self._scope_released = True
        scope = self._scope
        if self._pooled:
            self.conn._marshal_pool.push(scope)
        elif scope.live:
            scope.destroy()

    def _fail(self, exc: BaseException) -> None:
        self._state = _FAILED
        self._exc = exc
        self._release_scope_once()

    # -- the future surface ----------------------------------------------
    def done(self) -> bool:
        """Non-blocking: True once ``result`` will not wait."""
        return self._state != _PENDING or self.conn.poll(self.token)

    def _kick(self) -> None:
        """Transport hook: push any batched flight onto the wire (no-op
        on the CXL ring — the descriptor was posted at invoke time)."""

    def cancel(self) -> bool:
        """Abandon the call. Best-effort (an SPSC slot cannot be
        un-posted, so the server may still execute the handler); the
        reply scope and ring slot are reaped the moment the completion
        lands, and the marshal scope is recycled exactly once."""
        if self._state != _PENDING:
            return False
        conn = self.conn
        pending = conn._pending_async.get(self.token[0])
        self._state = _CANCELLED
        self._exc = ChannelError("future cancelled")
        if pending is not None:
            pending.cleanup = self._release_scope_once
            conn._abandon(self.token, pending)
        else:
            self._release_scope_once()
        return True

    def result(self, timeout: Optional[float] = None):
        """Block (with the §5.8 client back-off) until the reply lands;
        returns the decoded value or raises the RPC's error. A timeout
        raises ``ChannelError`` but leaves the future pending — call
        again, or ``cancel()`` to hand the slot to the reaper."""
        if self._state == _DONE:
            return self._value
        if self._state != _PENDING:
            raise self._exc
        conn = self.conn
        tmo = self._timeout if timeout is None else timeout
        if self._deadline_us:
            tmo = min(tmo, max(0.0,
                               self._deadline_us * 1e-6 - time.monotonic()))
        try:
            ret = conn.wait(self.token, sealed=self._sealed, timeout=tmo)
        except (DeadlineExceeded, RpcError) as e:
            self._fail(e)
            raise
        except ChannelError as e:
            if not conn.closed and \
                    self.token[0] in conn._pending_async:
                if self._deadline_us and _now_us() > self._deadline_us:
                    # the REQUEST deadline lapsed mid-wait: terminal.
                    # The slot cannot be un-posted, so hand it to the
                    # reaper (scope recycled when the completion lands)
                    # instead of leaving a zombie waiter.
                    exc = DeadlineExceeded("RPC deadline lapsed")
                    self._state = _FAILED
                    self._exc = exc
                    pending = conn._pending_async[self.token[0]]
                    pending.cleanup = self._release_scope_once
                    conn._abandon(self.token, pending)
                    raise exc from e
                raise   # pure wait timeout: still in flight, retryable
            self._fail(e)
            raise
        self._release_scope_once()
        self._value = _read_reply_graph(conn, ret)
        self._state = _DONE
        return self._value


def invoke_async_cxl(conn: Connection, fn_id: int, args: Tuple,
                     sealed: bool = False, sandboxed: bool = False,
                     deadline: Optional[float] = None,
                     timeout: float = 10.0) -> RpcFuture:
    """Pipelined typed invoke on the shared-memory ring: marshal (or
    pointer-pass a prebuilt graph), post, return — the reply is decoded
    whenever the future is settled. Up to ring-capacity invokes may be
    in flight per connection."""
    deadline_us = _deadline_word(deadline)

    if len(args) == 1 and isinstance(args[0], GraphRef):
        g = args[0]
        if g.scope is not None and g.scope.heap is conn.heap:
            conn.n_invokes += 1
            token = conn.call_async(fn_id, g.root, scope=g.scope,
                                    sealed=sealed, sandboxed=sandboxed,
                                    flags_extra=F_TYPED,
                                    deadline_us=deadline_us)
            fut = RpcFuture(conn, fn_id, token, None, False, sealed,
                            timeout, deadline_us)
            conn._track_async(token, sealed=sealed, typed=True)
            return fut
        args = tuple(g.to_python())

    root, scope, pooled = _pooled_marshal(conn, args, conn.client_pid,
                                          force_copy=sandboxed or sealed)
    try:
        token = conn.call_async(fn_id, root, scope=scope, sealed=sealed,
                                sandboxed=sandboxed, flags_extra=F_TYPED,
                                deadline_us=deadline_us)
    except BaseException:
        if pooled:
            conn._marshal_pool.push(scope)
        else:
            scope.destroy()
        raise
    conn.n_invokes += 1
    conn.marshal_bytes += scope.used_bytes()
    fut = RpcFuture(conn, fn_id, token, scope, pooled, sealed,
                    timeout, deadline_us)
    # close()/reap cleanup hook: drain this future's scope exactly once
    conn._track_async(token, sealed=sealed, typed=True,
                      cleanup=fut._release_scope_once)
    return fut


def gather(futures, timeout: float = 10.0) -> list:
    """Settle a batch of futures, consuming completions **as they land**
    (out-of-order draining — a slow first RPC never blocks the reaping
    of the seven behind it). Returns results in the order given; the
    first failed future raises after everything already completed was
    drained."""
    results = [None] * len(futures)
    pending = dict(enumerate(futures))
    failed: Optional[BaseException] = None
    deadline = time.monotonic() + timeout
    while pending:
        progressed = False
        for i, f in list(pending.items()):
            if not f.done():
                continue
            del pending[i]
            progressed = True
            try:
                results[i] = f.result(timeout=timeout)
            except BaseException as e:
                failed = failed or e
        if not pending:
            break
        if failed is not None:
            break   # drain what's already done, then surface the error
        if time.monotonic() > deadline:
            raise ChannelError(f"gather timed out with {len(pending)} "
                               "futures unsettled")
        if not progressed:
            # nothing ready: block on the oldest pending future in a
            # bounded slice (its result() waits through the connection's
            # §5.8 wait policy — no busy-poll here) after kicking any
            # batched flight onto the wire
            i, f = next(iter(pending.items()))
            f._kick()
            slice_s = min(0.05, max(0.005,
                                    deadline - time.monotonic()))
            try:
                results[i] = f.result(timeout=slice_s)
                del pending[i]
            except (DeadlineExceeded, RpcError) as e:
                failed = failed or e
                del pending[i]
            except ChannelError:
                pass   # wait-timeout slice: still in flight, re-loop
            except BaseException as e:
                failed = failed or e
                del pending[i]
    if failed is not None:
        raise failed
    return results


# ---------------------------------------------------------------------------
# invoke — CXL route (pointer passing)
# ---------------------------------------------------------------------------
def invoke_cxl(conn: Connection, fn_id: int, args: Tuple,
               sealed: bool = False, sandboxed: bool = False,
               batch_release: bool = False, timeout: float = 10.0,
               inline: bool = False, spin_sleep_us: float = 0.0,
               deadline: Optional[float] = None):
    """Typed invoke on the shared-memory ring: materialize-once, pass a
    pointer, decode the marshalled reply."""
    caller = conn.call_inline if inline else conn.call
    kw: Dict[str, Any] = {} if inline else \
        {"timeout": timeout, "spin_sleep_us": spin_sleep_us}
    if deadline is not None:
        kw["deadline_us"] = _deadline_word(deadline)

    # steady-state hot path: a single pre-built graph in this heap is
    # passed by pointer — zero marshalling work per call
    if len(args) == 1 and isinstance(args[0], GraphRef):
        g = args[0]
        if g.scope is not None and g.scope.heap is conn.heap:
            conn.n_invokes += 1
            ret = caller(fn_id, g.root, scope=g.scope, sealed=sealed,
                         sandboxed=sandboxed, batch_release=batch_release,
                         flags_extra=F_TYPED, **kw)
            return _read_reply_graph(conn, ret)
        # foreign-heap / plain ref: deep-copy the tuple across (§5.6)
        args = tuple(g.to_python())

    pid = conn.client_pid
    # sandboxed: the sandbox covers only the call scope, so embedded
    # graphs must be copied into it; sealed: the seal likewise protects
    # only the call scope — a pointer-embedded graph would stay sender-
    # writable mid-flight, the exact §4.5 TOCTOU sealing prevents
    root, scope, pooled = _pooled_marshal(conn, args, pid,
                                          force_copy=sandboxed or sealed)
    conn.n_invokes += 1
    conn.marshal_bytes += scope.used_bytes()
    try:
        ret = caller(fn_id, root, scope=scope, sealed=sealed,
                     sandboxed=sandboxed, batch_release=batch_release,
                     flags_extra=F_TYPED, **kw)
    finally:
        if not pooled:
            scope.destroy()
        elif sealed and batch_release:
            # pages stay write-protected until the batch flush (§5.3)
            conn._marshal_pool.push_sealed(scope, conn.last_seal_idx)
        else:
            conn._marshal_pool.push(scope)
    return _read_reply_graph(conn, ret)


# ---------------------------------------------------------------------------
# invoke — serialized routes (fallback transport + Fig. 11 baseline)
# ---------------------------------------------------------------------------
def _to_plain(v):
    """§5.6 copy semantics for a graph crossing a coherence boundary:
    the structural traversal materializes it (the ``deep_copy`` read
    half) and the result travels by value."""
    if isinstance(v, GraphRef):
        return v.to_python()
    return v


def _args_to_plain(args: Tuple) -> list:
    if len(args) == 1 and isinstance(args[0], GraphRef):
        return args[0].to_python()   # the ref IS the argument tuple
    return [_to_plain(v) for v in args]


def invoke_fallback(conn, fn_id: int, args: Tuple, sealed: bool = False,
                    sandboxed: bool = False, batch_release: bool = False,
                    timeout: float = 10.0, inline: bool = False,
                    deadline: Optional[float] = None, **_ignored):
    """Typed invoke over the software-coherent link: same surface, but
    the arguments are serial-encoded and travel by value (one blob copy
    over the wire instead of N page ping-pongs chasing pointers)."""
    payload = serial.encode(_args_to_plain(args))
    nbytes = _BLOB_HDR.size + len(payload)
    scope = conn.create_scope(nbytes)
    conn.n_invokes += 1
    conn.marshal_bytes += len(payload)
    try:
        a = scope.alloc(nbytes)
        conn.client.write(a, _BLOB_HDR.pack(len(payload)) + payload,
                          pid=conn.client_pid)
        ret = conn.call(fn_id, a, scope=scope, sealed=sealed,
                        sandboxed=sandboxed, batch_release=batch_release,
                        flags_extra=F_TYPED | F_BYVAL,
                        deadline_us=_deadline_word(deadline))
        # the reply blob faults its pages back over the link — the copy
        raw = _read_blob(conn.client, ret, conn.client.page_size)
        _recycle_reply(conn, ret)
        return serial.decode(raw)
    finally:
        scope.destroy()


class FallbackRpcFuture:
    """A pipelined invoke on the software-coherent link. Same surface as
    ``RpcFuture``; underneath, the descriptor+payload are *staged* and
    the whole flight crosses the wire on the first settlement (or an
    explicit ``conn.flush()``) — N staged invokes share one link-latency
    round trip instead of paying it N times."""

    __slots__ = ("conn", "fn_id", "slot", "_scope", "_sealed", "_seal_idx",
                 "_deadline_us", "_state", "_value", "_exc")

    def __init__(self, conn, fn_id: int, slot: int, scope: Scope,
                 sealed: bool, seal_idx: int, deadline_us: int):
        self.conn = conn
        self.fn_id = fn_id
        self.slot = slot
        self._scope = scope
        self._sealed = sealed
        self._seal_idx = seal_idx
        self._deadline_us = deadline_us
        self._state = _PENDING
        self._value = None
        self._exc: Optional[BaseException] = None

    def done(self) -> bool:
        if self._state != _PENDING:
            return True
        return not self.conn.in_flight(self.slot) and \
            self.conn.ring.state_of(self.slot) >= R_DONE

    def _kick(self) -> None:
        self.conn.flush()

    def cancel(self) -> bool:
        if self._state != _PENDING:
            return False
        self._state = _CANCELLED
        self._exc = ChannelError("future cancelled")
        self.conn.abandon_flight_entry(self.slot, self._scope,
                                       self._sealed, self._seal_idx)
        return True

    def result(self, timeout: Optional[float] = None):
        if self._state == _DONE:
            return self._value
        if self._state != _PENDING:
            raise self._exc
        conn = self.conn
        if conn.closed:
            self._state = _FAILED
            self._exc = ChannelError(
                "connection closed with the RPC in flight")
            raise self._exc
        if conn.in_flight(self.slot):
            conn.flush()
        ret, state, status = conn.ring.consume(self.slot)
        if self._sealed:
            conn.seals.release(self._seal_idx, holder=conn.client_pid)
        try:
            exc = conn._flight_errors.pop(self.slot, None)
            if exc is not None:
                raise exc
            if state == R_ERR:
                raise DeadlineExceeded("RPC deadline lapsed") \
                    if status == E_DEADLINE else RpcError(status)
            # the reply pages were bulk-migrated back by the flush; this
            # read is local (a straggler still faults correctly)
            raw = _read_blob(conn.client, ret, conn.client.page_size)
            _recycle_reply(conn, ret)
            self._value = serial.decode(raw)
        except BaseException as e:
            self._state = _FAILED
            self._exc = e
            raise
        finally:
            if self._scope.live:
                self._scope.destroy()
            conn.n_calls += 1
        self._state = _DONE
        return self._value


def invoke_async_fallback(conn, fn_id: int, args: Tuple,
                          sealed: bool = False, sandboxed: bool = False,
                          deadline: Optional[float] = None,
                          timeout: float = 10.0,
                          **_ignored) -> FallbackRpcFuture:
    """Stage a typed by-value invoke for the next pipelined flight (§5.6
    copy semantics, cMPI-style latency amortization)."""
    payload = serial.encode(_args_to_plain(args))
    nbytes = _BLOB_HDR.size + len(payload)
    scope = conn.create_scope(nbytes)
    deadline_us = _deadline_word(deadline)
    try:
        a = scope.alloc(nbytes)
        conn.client.write(a, _BLOB_HDR.pack(len(payload)) + payload,
                          pid=conn.client_pid)
        slot = conn.post_async(fn_id, a, scope, sealed=sealed,
                               sandboxed=sandboxed,
                               flags_extra=F_TYPED | F_BYVAL,
                               deadline_us=deadline_us)
    except BaseException:
        scope.destroy()
        raise
    conn.n_invokes += 1
    conn.marshal_bytes += len(payload)
    seal_idx = conn.ring.seal_idx[slot]
    return FallbackRpcFuture(conn, fn_id, slot, scope, sealed,
                             int(seal_idx), deadline_us)


def invoke_serialized(conn: Connection, fn_id: int, args: Tuple,
                      sealed: bool = False, sandboxed: bool = False,
                      timeout: float = 10.0, inline: bool = False,
                      spin_sleep_us: float = 0.0,
                      deadline: Optional[float] = None):
    """The serializing baseline on the SAME CXL descriptor ring: encode,
    copy the blob through shared memory, full decode on the receiver,
    encode+decode the reply. Everything Fig. 11 shows RPCool avoiding,
    with the ring machinery held identical."""
    caller = conn.call_inline if inline else conn.call
    kw: Dict[str, Any] = {} if inline else \
        {"timeout": timeout, "spin_sleep_us": spin_sleep_us}
    if deadline is not None:
        kw["deadline_us"] = _deadline_word(deadline)
    payload = serial.encode(_args_to_plain(args))
    nbytes = _BLOB_HDR.size + len(payload)

    pid = conn.client_pid
    pooled = nbytes <= MARSHAL_SCOPE_PAGES * conn.heap.page_size
    if pooled:
        scope = _marshal_pool(conn).pop()
    else:
        scope = create_scope(conn.heap, nbytes, owner=pid)
    try:
        a = scope.alloc(nbytes)
        conn.heap.write(a, _BLOB_HDR.pack(len(payload)) + payload, pid=pid)
        ret = caller(fn_id, a, scope=scope, sealed=sealed,
                     sandboxed=sandboxed, flags_extra=F_TYPED | F_BYVAL,
                     **kw)
    finally:
        if pooled:
            conn._marshal_pool.push(scope)
        else:
            scope.destroy()
    raw = _read_blob(conn.heap, ret, conn.heap.page_size)
    _recycle_reply(conn, ret)
    return serial.decode(raw)
