"""Global orchestrator — leases, quotas, registry, failure GC (§4.6, §5.4).

The orchestrator is the cluster-level control plane: it assigns heap ids
(hence globally-unique address spaces), registers channels under
hierarchical names, tracks which process has which heap mapped via
*leases*, enforces per-process shared-memory *quotas*, and garbage-collects
orphaned heaps when every lease on them has lapsed.

It also keeps the *coherence-domain* registry (§4.6–§4.7): every process
may be assigned to a named pod; two processes share hardware cache
coherence iff they are in the same pod. ``ClusterRouter`` consults
exactly this metadata — nothing else — to decide whether a connection
gets the CXL ring data plane or the RDMA-style fallback transport.

Time is injected (``clock``) so tests and benchmarks can drive lease expiry
deterministically; production uses ``time.monotonic``.

Failure model reproduced from Fig. 5:
  (a) server crash → its leases lapse → orchestrator notifies connected
      clients; the heap survives while any client still holds a lease and
      is reclaimed when the last lease closes.
  (b) client hoarding heaps from dead servers → quota forces it to return
      heaps before mapping new ones.
"""

from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Set, Tuple

from .errors import ChannelError, QuotaExceeded
from .heap import SharedHeap

DEFAULT_LEASE_TTL = 5.0  # seconds; librpcool auto-renews at ttl/2


@dataclass
class Lease:
    pid: int
    heap_id: int
    expires: float
    live: bool = True


class Orchestrator:
    def __init__(self, clock: Optional[Callable[[], float]] = None,
                 lease_ttl: float = DEFAULT_LEASE_TTL):
        self.clock = clock or time.monotonic
        self.lease_ttl = lease_ttl

        self._next_heap_id = 1
        # pid mint for processes the orchestrator itself brings up (e.g.
        # warm replicas restored from a snapshot) — high base so it never
        # collides with caller-chosen pids
        self._next_pid = 1_000_000
        self.heaps: Dict[int, SharedHeap] = {}
        self.channels: Dict[str, object] = {}  # name -> Channel
        self._leases: Dict[Tuple[int, int], Lease] = {}  # (pid, heap) -> lease
        self._quota: Dict[int, int] = {}  # pid -> max mapped bytes
        # §5.4 traffic quotas: pid -> admitted requests/second. The
        # orchestrator only owns the table (like the memory quotas); the
        # server-side AdmissionInterceptor enforces it pre-dispatch.
        self._req_quota: Dict[int, float] = {}
        # §5.4 pool-page quotas: pid -> max pages owned at once inside a
        # registered pool heap. Same contract as the other quotas: this
        # table is authoritative, the pool's allocator enforces it (an
        # over-quota admit sheds with Overloaded, never a silent grant).
        self._page_quota: Dict[int, int] = {}
        # pod -> shared pool (e.g. the KV pool serving that pod): the
        # byref argument resolver looks the *destination* pool up here
        # when a pool-page RPC crosses coherence domains
        self._pod_pools: Dict[str, object] = {}
        self._mapped: Dict[int, Set[int]] = {}  # pid -> heap ids
        self._failure_cbs: List[Callable[[int, int], None]] = []
        # coherence domains: pod name -> member pids (§4.6)
        self.pods: Dict[str, Set[int]] = {}
        self._pod_of: Dict[int, str] = {}
        # stats
        self.reclaimed_heaps = 0
        self.expired_leases = 0

    # -- coherence domains ---------------------------------------------------
    def assign_pod(self, pid: int, pod: str) -> None:
        """Place ``pid`` in coherence domain ``pod`` (one pod per pid)."""
        old = self._pod_of.get(pid)
        if old is not None:
            self.pods[old].discard(pid)
        self._pod_of[pid] = pod
        self.pods.setdefault(pod, set()).add(pid)

    def pod_of(self, pid: int) -> Optional[str]:
        return self._pod_of.get(pid)

    def same_domain(self, pid_a: int, pid_b: int) -> bool:
        """True iff the two processes share hardware cache coherence.
        A pid with no pod assignment is treated as local (single-host
        deployments never register pods and always get the CXL path)."""
        pa, pb = self._pod_of.get(pid_a), self._pod_of.get(pid_b)
        return pa is None or pb is None or pa == pb

    def alloc_pid(self) -> int:
        """A fresh process id for an orchestrator-spawned worker (a
        restored snapshot replica); monotonically unique per instance."""
        pid = self._next_pid
        self._next_pid += 1
        return pid

    def alloc_heap_id(self) -> int:
        """Reserve a cluster-unique heap id without creating a heap here
        (the fallback transport instantiates its own replica pair)."""
        hid = self._next_heap_id
        self._next_heap_id += 1
        return hid

    # -- heap lifecycle ------------------------------------------------------
    def create_heap(self, num_pages: int, page_size: int = 4096,
                    name: str = "") -> SharedHeap:
        hid = self.alloc_heap_id()
        heap = SharedHeap(hid, num_pages, page_size, name=name)
        self.heaps[hid] = heap
        return heap

    def map_heap(self, pid: int, heap: SharedHeap) -> Lease:
        """Map a heap into a process — checks quota, grants a lease."""
        quota = self._quota.get(pid)
        mapped = self._mapped.setdefault(pid, set())
        if quota is not None:
            projected = sum(
                self.heaps[h].num_pages * self.heaps[h].page_size
                for h in mapped | {heap.heap_id}
                if h in self.heaps
            )
            if projected > quota:
                raise QuotaExceeded(
                    f"pid {pid}: mapping heap {heap.heap_id} "
                    f"({projected}B) exceeds quota {quota}B; "
                    "close existing channels first (§5.4)"
                )
        lease = Lease(pid, heap.heap_id, self.clock() + self.lease_ttl)
        self._leases[(pid, heap.heap_id)] = lease
        mapped.add(heap.heap_id)
        return lease

    def unmap_heap(self, pid: int, heap_id: int) -> None:
        self._leases.pop((pid, heap_id), None)
        self._mapped.get(pid, set()).discard(heap_id)
        self._maybe_reclaim(heap_id)

    def renew(self, pid: int) -> int:
        """librpcool's periodic lease renewal for every heap of ``pid``."""
        now = self.clock()
        n = 0
        for (p, h), lease in self._leases.items():
            if p == pid and lease.live:
                lease.expires = now + self.lease_ttl
                n += 1
        return n

    def set_quota(self, pid: int, max_bytes: int) -> None:
        self._quota[pid] = max_bytes

    def set_request_quota(self, pid: int,
                          per_second: Optional[float]) -> None:
        """§5.4 traffic quota: cap the request rate the cluster admits
        from ``pid`` (``None`` clears the cap). Enforcement happens in
        the servers' ``AdmissionInterceptor`` token buckets, which read
        this table and this orchestrator's ``clock`` — so tests can
        drive refills deterministically."""
        if per_second is None:
            self._req_quota.pop(pid, None)
        else:
            self._req_quota[pid] = float(per_second)

    def request_quota(self, pid: int) -> Optional[float]:
        return self._req_quota.get(pid)

    def set_page_quota(self, pid: int, max_pages: Optional[int]) -> None:
        """§5.4 pool-page quota: cap how many pool pages ``pid`` may own
        at once (``None`` clears the cap). Enforced by the pool
        allocator, which sheds over-quota admits with ``Overloaded``."""
        if max_pages is None:
            self._page_quota.pop(pid, None)
        else:
            self._page_quota[pid] = int(max_pages)

    def page_quota(self, pid: int) -> Optional[int]:
        return self._page_quota.get(pid)

    # -- pod pool registry (cross-pod byref resolution) ------------------------
    def register_pool(self, pod: str, pool: object) -> None:
        """Publish ``pool`` as coherence domain ``pod``'s shared pool.
        A byref pool-page argument dispatched *into* that pod resolves
        its destination pages against this registry."""
        self._pod_pools[pod] = pool

    def pool_of_pod(self, pod: str) -> object:
        try:
            return self._pod_pools[pod]
        except KeyError:
            raise ChannelError(f"no pool registered for pod {pod!r}")

    def mapped_bytes(self, pid: int) -> int:
        return sum(
            self.heaps[h].num_pages * self.heaps[h].page_size
            for h in self._mapped.get(pid, set())
            if h in self.heaps
        )

    # -- failure handling ------------------------------------------------------
    def on_failure(self, cb: Callable[[int, int], None]) -> None:
        """cb(pid, heap_id) fired when a lease expires."""
        self._failure_cbs.append(cb)

    def expire_leases(self, pid: int) -> int:
        """Force every live lease of ``pid`` to lapse on the next
        ``tick()`` — the deterministic ops/chaos form of "the process
        died" (Fig. 5a), without waiting out the TTL on a wall clock.
        Returns the number of leases marked."""
        n = 0
        for (p, _h), lease in self._leases.items():
            if p == pid and lease.live:
                lease.expires = float("-inf")
                n += 1
        return n

    def tick(self) -> List[Tuple[int, int]]:
        """Expire lapsed leases, notify peers, GC orphaned heaps.

        Returns the list of (pid, heap_id) leases that expired this tick.
        """
        now = self.clock()
        expired = []
        for key, lease in list(self._leases.items()):
            if lease.live and lease.expires < now:
                lease.live = False
                expired.append(key)
        for pid, heap_id in expired:
            self.expired_leases += 1
            del self._leases[(pid, heap_id)]
            self._mapped.get(pid, set()).discard(heap_id)
            for cb in self._failure_cbs:
                cb(pid, heap_id)
            self._maybe_reclaim(heap_id)
        return expired

    def _maybe_reclaim(self, heap_id: int) -> None:
        if heap_id not in self.heaps:
            return
        if any(h == heap_id and l.live for (_, h), l in self._leases.items()):
            return
        # Last process accessing the heap is gone → reclaim (§5.4).
        del self.heaps[heap_id]
        self.reclaimed_heaps += 1

    def live_leases(self, heap_id: Optional[int] = None) -> int:
        return sum(
            1 for (_, h), l in self._leases.items()
            if l.live and (heap_id is None or h == heap_id)
        )

    # -- channel registry ------------------------------------------------------
    def register_channel(self, name: str, channel: object) -> None:
        if name in self.channels:
            raise ChannelError(f"channel {name!r} already registered")
        self.channels[name] = channel

    def lookup_channel(self, name: str) -> object:
        try:
            return self.channels[name]
        except KeyError:
            raise ChannelError(f"no such channel {name!r}")

    def unregister_channel(self, name: str) -> None:
        self.channels.pop(name, None)
