"""RPCool core — the paper's contribution as a composable library.

Layers (bottom-up):
  addr        globally-unique packed addresses (orchestrator-assigned VAs)
  heap        SharedHeap: paged shared memory + permissions + epochs
  scope       contiguous page ranges bounding one RPC's arguments
  seal        Fig. 8 seal()/release() protocol + batched release
  sandbox     MPK-analogue pointer confinement, 14 cached sandboxes
  containers  heap-resident pointer-rich objects (Boost.Interprocess analogue)
  channel     channels/connections/RPC rings + §5.8 busy-wait policy,
              ServerLoop (one thread serving every ring of N channels)
  orchestrator leases, quotas, registry, pods, failure GC
  fallback    two-node software-coherent DSM (RDMA/DCN analogue)
  router      ClusterRouter: hierarchical endpoint names → CXL or
              fallback transport, lease heartbeats, replica failover,
              wildcard prefix stubs, live migration (migrate)
  lifecycle   Endpoint handle: serve/quiesce/drain/close states over
              Channel.serve + ServerLoop
  snapshot    snapshot/restore: portable service checkpoints → warm
              replicas (the migrate primitive)
  serial      serializing wire format (gRPC analogue: the fallback
              route's by-value payload + the Fig. 11 baseline)
  marshal     typed zero-copy data plane: conn.invoke(fn, *values),
              ArgView handler views, GraphRef pointer reuse,
              per-route pointer-vs-copy marshalling
"""

from . import addr
from .errors import (
    AllocationError,
    ChannelError,
    DeadlineExceeded,
    InvalidPointer,
    LeaseExpired,
    Overloaded,
    OwnershipMiss,
    QuotaExceeded,
    RPCoolError,
    SandboxViolation,
    SealedPageError,
    SealViolation,
)
from .heap import PERM_SEALED, SharedHeap
from .scope import Scope, ScopePool, create_scope
from .seal import SealManager, S_COMPLETE, S_RELEASED, S_SEALED
from .sandbox import MAX_CACHED, Sandbox, SandboxManager
from .orchestrator import Lease, Orchestrator
from .channel import (
    BusyWaitPolicy,
    Channel,
    Connection,
    DescriptorRing,
    RING_DTYPE,
    RPC,
    RpcError,
    ServerCtx,
    ServerLoop,
    E_DEADLINE,
    E_OVERLOAD,
    F_BYVAL,
    F_DEADLINE,
    F_SANDBOXED,
    F_SEALED,
    F_STREAM,
    F_TYPED,
)
from .fallback import DSMLink, DSMNode, FallbackConnection
from .router import BalancedConnection, ClusterRouter, EndpointRecord, \
    MigrationReport, RoutedConnection, RoutedRpcFuture, RoutedRpcStream, \
    WildcardConnection
from .lifecycle import CLOSED, DRAINED, Endpoint, QUIESCED, QuiesceGate, \
    SERVING
from .snapshot import RestoredEndpoint, Snapshot, restore, snapshot, \
    sync_state
from .chaos import ChaosInjector, Fault, FaultPlan, KINDS
from . import containers, serial
from . import marshal
from .marshal import ArgView, FallbackRpcFuture, FallbackRpcStream, \
    GraphRef, RpcFuture, RpcStream, ServerStream, build_graph, gather
from .service import (
    AdmissionInterceptor,
    DeadlineEnforcer,
    Interceptor,
    MethodSpec,
    RetryInterceptor,
    ServiceDef,
    ServiceStub,
    StatsInterceptor,
    StubMethod,
    method,
    service,
    service_def,
    stable_fn_id,
)

__all__ = [
    "addr",
    "AllocationError", "ChannelError", "DeadlineExceeded",
    "InvalidPointer", "LeaseExpired", "Overloaded",
    "OwnershipMiss", "QuotaExceeded", "RPCoolError", "SandboxViolation",
    "SealedPageError", "SealViolation",
    "PERM_SEALED", "SharedHeap",
    "Scope", "ScopePool", "create_scope",
    "SealManager", "S_COMPLETE", "S_RELEASED", "S_SEALED",
    "MAX_CACHED", "Sandbox", "SandboxManager",
    "Lease", "Orchestrator",
    "BusyWaitPolicy", "Channel", "Connection", "DescriptorRing",
    "RING_DTYPE", "RPC", "RpcError",
    "ServerCtx", "ServerLoop", "E_DEADLINE", "E_OVERLOAD", "F_BYVAL",
    "F_DEADLINE", "F_SANDBOXED", "F_SEALED", "F_STREAM", "F_TYPED",
    "DSMLink", "DSMNode", "FallbackConnection",
    "BalancedConnection", "ClusterRouter", "EndpointRecord",
    "MigrationReport", "RoutedConnection",
    "RoutedRpcFuture", "RoutedRpcStream", "WildcardConnection",
    "CLOSED", "DRAINED", "Endpoint", "QUIESCED", "QuiesceGate", "SERVING",
    "RestoredEndpoint", "Snapshot", "restore", "snapshot", "sync_state",
    "ChaosInjector", "Fault", "FaultPlan", "KINDS",
    "containers", "serial", "marshal",
    "ArgView", "FallbackRpcFuture", "FallbackRpcStream", "GraphRef",
    "RpcFuture", "RpcStream", "ServerStream", "build_graph", "gather",
    "AdmissionInterceptor", "DeadlineEnforcer", "Interceptor",
    "MethodSpec", "RetryInterceptor",
    "ServiceDef", "ServiceStub", "StatsInterceptor", "StubMethod",
    "method", "service", "service_def", "stable_fn_id",
]
