"""Deterministic chaos injection for the overload/soak gates.

The robustness story of the paper's traffic plane (§5.4 quotas, Fig. 5
lease-lapse failover, §5.8 busy-wait backpressure) is only credible if it
survives *mixed* faults under load. This module provides the harness the
``--suite soak`` bench (and the chaos tests) drive:

* ``Fault`` — one scheduled disturbance: a *kind*, the traffic-progress
  fraction ``at`` where it fires, how long it stays active (``duration``,
  also in progress fraction; ``0`` = one-shot), and an optional
  ``target`` (a pid, an endpoint name — whatever the kind's binding
  interprets).
* ``FaultPlan`` — an ordered, **seedable** set of faults.
  ``FaultPlan.default(seed)`` covers the four fault families the soak
  gate requires, each jittered inside its own progress band so distinct
  seeds reorder *timing* but never *coverage*.
* ``ChaosInjector`` — applies the plan. It is poll-driven and clockless:
  the bench's main loop calls ``poke(progress)`` with its own notion of
  progress (requests completed / requests planned), and the injector
  fires every due fault and reverts every expired one. Determinism
  follows: same seed + same traffic schedule → same faults at the same
  requests.

Fault kinds (``KINDS``):

``slow_handler``    server-side latency spike (bench binds: handler sleeps)
``ring_stall``      a serving loop stops draining its rings (bench binds:
                    detach/attach the channel) — exercises the bounded
                    admission queue and typed ``Overloaded`` shedding
``quota_exhaust``   the orchestrator's §5.4 request quota for a client
                    drops to zero (built-in binding) — every request
                    sheds with ``E_OVERLOAD`` until reverted
``lease_lapse``     a serving pid's leases lapse (built-in binding):
                    Fig. 5a server death → balancer drops the replica
``endpoint_death``  every replica of an endpoint lapses (built-in
                    binding) — the worst case; routed calls surface
                    ``ChannelError`` until a replica re-registers

Built-in bindings need an ``Orchestrator`` (and optionally the
``ClusterRouter``) at construction; ``bind()`` overrides or adds kinds.
Firing a fault whose kind has no binding raises — a chaos plan that
silently skips faults would green-light an ungated run.
"""

from __future__ import annotations

import random
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence

from .errors import ChannelError

KINDS = ("slow_handler", "ring_stall", "quota_exhaust",
         "lease_lapse", "endpoint_death")

# FaultPlan.default(): one band per fault family. Jitter moves `at`
# inside the band; bands never overlap, so every seed keeps the same
# coverage AND the same fault order.
_DEFAULT_BANDS = (
    ("slow_handler",  0.10, 0.20, 0.05),
    ("ring_stall",    0.30, 0.40, 0.08),
    ("quota_exhaust", 0.50, 0.60, 0.10),
    ("lease_lapse",   0.70, 0.80, 0.00),   # one-shot: the pid stays dead
)


@dataclass(frozen=True)
class Fault:
    kind: str
    at: float                       # progress fraction in [0, 1)
    duration: float = 0.0           # progress the fault stays active
    target: Optional[object] = None  # pid / endpoint name / kind-specific

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ChannelError(
                f"unknown fault kind {self.kind!r} (want one of {KINDS})")
        if not (0.0 <= self.at <= 1.0) or self.duration < 0.0:
            raise ChannelError(
                f"fault {self.kind}: at={self.at} duration={self.duration} "
                "must satisfy 0 <= at <= 1, duration >= 0")

    @property
    def until(self) -> float:
        return self.at + self.duration


class FaultPlan:
    """An ordered, seed-reproducible schedule of faults."""

    def __init__(self, faults: Sequence[Fault], seed: int = 0):
        self.seed = seed
        self.faults: List[Fault] = sorted(faults, key=lambda f: f.at)

    @classmethod
    def default(cls, seed: int = 0,
                targets: Optional[Dict[str, object]] = None) -> "FaultPlan":
        """The soak gate's standard mix: every fault family in
        ``_DEFAULT_BANDS``, fire points jittered inside their bands by
        ``seed``. ``targets`` maps kind → target (e.g. the pid to lapse);
        a missing entry leaves the target to the binding's default."""
        rng = random.Random(seed)
        targets = targets or {}
        faults = [
            Fault(kind, at=lo + rng.random() * (hi - lo), duration=dur,
                  target=targets.get(kind))
            for kind, lo, hi, dur in _DEFAULT_BANDS
        ]
        return cls(faults, seed=seed)

    def __iter__(self):
        return iter(self.faults)

    def __len__(self) -> int:
        return len(self.faults)

    def __repr__(self) -> str:  # pragma: no cover
        inner = ", ".join(f"{f.kind}@{f.at:.2f}" for f in self.faults)
        return f"<FaultPlan seed={self.seed} [{inner}]>"


class ChaosInjector:
    """Applies a ``FaultPlan`` as traffic progresses.

    Poll-driven: the load generator calls ``poke(progress)`` from its
    main loop; the injector fires every pending fault whose ``at`` has
    been reached and reverts every active fault whose window lapsed.
    ``finish()`` reverts anything still active (call it before gating so
    a fault window that spans the end of traffic cannot leak state into
    the measurement epilogue).
    """

    def __init__(self, plan: FaultPlan,
                 orch=None, router=None):
        self.plan = plan
        self.orch = orch
        self.router = router
        self._apply: Dict[str, Callable[[Fault], None]] = {}
        self._revert: Dict[str, Callable[[Fault], None]] = {}
        self._pending: List[Fault] = list(plan)
        self._active: List[Fault] = []
        self.fired: List[Fault] = []
        self.reverted: List[Fault] = []
        self._saved_quota: Dict[int, Optional[float]] = {}
        if orch is not None:
            self._apply["quota_exhaust"] = self._quota_apply
            self._revert["quota_exhaust"] = self._quota_revert
            self._apply["lease_lapse"] = self._lapse_apply
            self._apply["endpoint_death"] = self._death_apply

    # -- bindings ------------------------------------------------------------
    def bind(self, kind: str, apply: Callable[[Fault], None],
             revert: Optional[Callable[[Fault], None]] = None) -> None:
        if kind not in KINDS:
            raise ChannelError(f"unknown fault kind {kind!r}")
        self._apply[kind] = apply
        if revert is not None:
            self._revert[kind] = revert

    def _quota_apply(self, fault: Fault) -> None:
        pid = int(fault.target)
        self._saved_quota[pid] = self.orch.request_quota(pid)
        self.orch.set_request_quota(pid, 0.0)   # shed everything

    def _quota_revert(self, fault: Fault) -> None:
        pid = int(fault.target)
        self.orch.set_request_quota(pid, self._saved_quota.pop(pid, None))

    def _kill_pid(self, pid: int) -> None:
        # stop heartbeating FIRST so the router cannot renew the lease
        # back to life between the lapse and the expiry tick
        if self.router is not None:
            self.router.mark_crashed(pid)
        self.orch.expire_leases(pid)

    def _lapse_apply(self, fault: Fault) -> None:
        self._kill_pid(int(fault.target))
        self.orch.tick()   # fire the failure callbacks now — determinism

    def _death_apply(self, fault: Fault) -> None:
        ep = self.router.resolve(str(fault.target))
        for ch in ep.chain:
            self._kill_pid(ch.server_pid)
        self.orch.tick()

    # -- the drive loop ------------------------------------------------------
    def poke(self, progress: float) -> List[Fault]:
        """Fire/revert everything due at ``progress`` ∈ [0, 1]. Returns
        the faults newly fired by this poke."""
        now_fired: List[Fault] = []
        while self._pending and self._pending[0].at <= progress:
            fault = self._pending.pop(0)
            apply = self._apply.get(fault.kind)
            if apply is None:
                raise ChannelError(
                    f"fault {fault.kind!r} fired with no binding — "
                    "bind() it (or pass orch/router for the built-ins)")
            apply(fault)
            self.fired.append(fault)
            now_fired.append(fault)
            if fault.duration > 0.0 and fault.kind in self._revert:
                self._active.append(fault)
        still = []
        for fault in self._active:
            if fault.until <= progress:
                self._revert[fault.kind](fault)
                self.reverted.append(fault)
            else:
                still.append(fault)
        self._active = still
        return now_fired

    def finish(self) -> None:
        """Revert every still-active fault (end of traffic)."""
        for fault in self._active:
            self._revert[fault.kind](fault)
            self.reverted.append(fault)
        self._active = []

    @property
    def n_fired(self) -> int:
        return len(self.fired)

    def __repr__(self) -> str:  # pragma: no cover
        return (f"<ChaosInjector fired={len(self.fired)} "
                f"active={len(self._active)} pending={len(self._pending)}>")
