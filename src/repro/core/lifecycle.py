"""Unified endpoint lifecycle: one handle over serve/quiesce/drain/close.

Serving a channel today means juggling three surfaces — ``Channel.serve``
registers the handlers, ``Channel.serve_all``/``ServerLoop`` runs the
sweep thread, and teardown is an ad-hoc mix of ``stop()``/``destroy()``
calls. ``Endpoint`` folds them into one handle with explicit states::

    SERVING ──quiesce()──▶ QUIESCED ──drain()──▶ DRAINED ──close()──▶ CLOSED
       ▲                      │
       └──────resume()────────┘

* ``quiesce()`` installs a :class:`QuiesceGate` on every channel: new
  requests shed with typed ``Overloaded`` (carrying a retry-after hint)
  while requests already admitted keep running. This is §5.4 admission
  turned into a drain valve.
* ``drain()`` waits for the serve loop to settle everything in flight —
  posted ring slots served, stream chunk-chains ended — within a bounded
  budget. The loop keeps running; ``drain`` only *watches* the rings, so
  there is never a second thread sweeping an SPSC ring.
* ``close()`` stops the loop and destroys the channels (idempotent).

The old entry points remain supported verbatim — ``Channel.serve`` /
``serve_all`` / ``ServerLoop`` are what this handle drives underneath —
so existing code keeps working; ``Endpoint.serve(...)`` is the
recommended spelling. Live migration (``ClusterRouter.migrate``) uses
exactly these states on the source endpoint.
"""

from __future__ import annotations

import time
from typing import List, Optional, Sequence, Union

from .channel import BusyWaitPolicy, Channel, R_REQ, ServerLoop
from .errors import ChannelError

# lifecycle states (string-valued for cheap debugging/reprs)
SERVING = "serving"
QUIESCED = "quiesced"
DRAINED = "drained"
CLOSED = "closed"


class QuiesceGate:
    """Admission gate that sheds *every* new request with typed
    ``Overloaded`` (+ retry-after hint) while leaving already-admitted
    work untouched. Wraps whatever gate was installed before so the
    service's own admission policy is restored on ``resume()``."""

    def __init__(self, prev=None, retry_after_s: float = 0.002):
        self.prev = prev
        self.retry_after_s = retry_after_s
        self.n_shed = 0

    def admit(self, client_pid: int, fn_id: int) -> Optional[int]:
        self.n_shed += 1
        return max(1, int(self.retry_after_s * 1e6))

    def release(self) -> None:
        # releases always belong to work admitted by the wrapped gate
        # (this gate never admits), so forward them
        if self.prev is not None:
            self.prev.release()


def _channel_busy(ch: Channel) -> bool:
    """True while the serve loop still owes work: a posted-but-unserved
    ring slot or a live stream chunk-chain."""
    if ch._streams:
        return True
    for conn in list(ch.connections):
        state = getattr(conn.ring, "state", None)
        if state is not None and bool((state == R_REQ).any()):
            return True
    return False


class Endpoint:
    """The unified serve/quiesce/drain/close handle over one or more
    channels publishing a single service instance."""

    def __init__(self, channels: Union[Channel, Sequence[Channel]],
                 instance=None, interceptors=(),
                 policy: Optional[BusyWaitPolicy] = None,
                 start: bool = True):
        chs: List[Channel] = [channels] if isinstance(channels, Channel) \
            else list(channels)
        if not chs:
            raise ChannelError("Endpoint needs at least one channel")
        self.channels = chs
        self.instance = instance
        self.interceptors = tuple(interceptors)
        self._policy = policy
        self._loop: Optional[ServerLoop] = None
        self._state = QUIESCED  # not serving until start()
        self._gates: List[QuiesceGate] = []
        self.n_shed = 0  # sheds across every quiesce window so far
        for ch in chs:
            if instance is not None and ch.served_instance is None:
                ch.serve(instance, interceptors)
            ch.lifecycle = self
        if start:
            self.start()

    @classmethod
    def serve(cls, channels: Union[Channel, Sequence[Channel]],
              instance=None, interceptors=(),
              policy: Optional[BusyWaitPolicy] = None) -> "Endpoint":
        """Register ``instance`` on the channel(s) and start serving from
        one background ``ServerLoop`` — the one-call replacement for
        ``Channel.serve`` + ``Channel.serve_all``."""
        return cls(channels, instance, interceptors, policy)

    # -- state ---------------------------------------------------------------
    @property
    def state(self) -> str:
        return self._state

    @property
    def loop(self) -> Optional[ServerLoop]:
        return self._loop

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        names = ",".join(ch.name for ch in self.channels)
        return f"<Endpoint {names} {self._state}>"

    # -- transitions ---------------------------------------------------------
    def start(self, policy: Optional[BusyWaitPolicy] = None) -> "Endpoint":
        """(Re)start serving. Idempotent while SERVING."""
        if self._state == CLOSED:
            raise ChannelError("Endpoint is closed")
        if self._loop is None or not self._loop.running:
            self._loop = Channel.serve_all(
                self.channels, policy or self._policy)
        self._state = SERVING
        return self

    def quiesce(self, retry_after_s: Optional[float] = None) -> int:
        """Stop admitting: every channel gets a :class:`QuiesceGate`, so
        new requests shed with typed ``Overloaded`` while in-flight work
        keeps running. Returns the number of channels gated. Idempotent
        while QUIESCED/DRAINED."""
        if self._state == CLOSED:
            raise ChannelError("Endpoint is closed")
        if self._gates:
            return 0
        if retry_after_s is None:
            retry_after_s = self.channels[0].config.migrate_retry_after_s
        for ch in self.channels:
            gate = QuiesceGate(ch.admission, retry_after_s)
            ch.admission = gate
            self._gates.append(gate)
        self._state = QUIESCED
        return len(self._gates)

    def resume(self) -> "Endpoint":
        """Lift the quiesce gates and go back to SERVING."""
        if self._state == CLOSED:
            raise ChannelError("Endpoint is closed")
        for ch, gate in zip(self.channels, self._gates):
            if ch.admission is gate:  # don't clobber a newer gate
                ch.admission = gate.prev
            self.n_shed += gate.n_shed
        self._gates.clear()
        self._state = SERVING
        return self

    def drain(self, timeout_s: float = 2.0,
              poll_s: float = 200e-6) -> bool:
        """Quiesce (if not already) and wait for the serve loop to settle
        everything in flight: posted slots served, stream chains ended.
        Returns True if the endpoint went idle within ``timeout_s``.
        The serve loop keeps running — drain only watches."""
        if self._state == CLOSED:
            raise ChannelError("Endpoint is closed")
        self.quiesce()
        deadline = time.monotonic() + timeout_s
        while time.monotonic() < deadline:
            if not any(_channel_busy(ch) for ch in self.channels):
                self._state = DRAINED
                return True
            time.sleep(poll_s)
        return False

    def close(self, timeout_s: float = 2.0) -> None:
        """Stop the serve loop and destroy every channel. Draining first
        (bounded by ``timeout_s``) keeps in-flight callers from seeing a
        hard close; work still pending after the budget is aborted by
        ``Channel.destroy``. Idempotent."""
        if self._state == CLOSED:
            return
        if self._state != DRAINED:
            self.drain(timeout_s)
        for gate in self._gates:
            self.n_shed += gate.n_shed
        self._gates.clear()
        if self._loop is not None:
            self._loop.stop(join=True)
            self._loop = None
        for ch in self.channels:
            ch.lifecycle = None
            ch.destroy()
        self._state = CLOSED

    # -- context manager -----------------------------------------------------
    def __enter__(self) -> "Endpoint":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
