"""Serializing transport — the gRPC/Thrift-analogue baseline (§2).

Everything RPCool exists to avoid: arguments are flattened to bytes,
copied through a message buffer, and rebuilt on the far side. Used by the
benchmark harness as the traditional-RPC baseline for Table 1a / Fig. 11:
same ring machinery as the zero-copy channel so the *only* difference
measured is serialize+copy+deserialize.

The wire format is a compact tag-length-value encoding (protobuf-class,
no schema compilation) over the same object model as ``containers``.
"""

from __future__ import annotations

import struct
import threading
import time
from typing import Any, Callable, Dict, Optional

from .errors import ChannelError

_TAG_NONE = 0
_TAG_INT = 1
_TAG_FLOAT = 2
_TAG_STR = 3
_TAG_LIST = 4
_TAG_DICT = 5
_TAG_BYTES = 6


def encode(obj: Any, out: Optional[bytearray] = None) -> bytes:
    buf = out if out is not None else bytearray()
    _enc(obj, buf)
    return bytes(buf)


def _enc(obj: Any, buf: bytearray) -> None:
    if obj is None:
        buf.append(_TAG_NONE)
    elif isinstance(obj, bool):
        buf.append(_TAG_INT)
        buf += struct.pack("<q", int(obj))
    elif isinstance(obj, int):
        buf.append(_TAG_INT)
        buf += struct.pack("<q", obj)
    elif isinstance(obj, float):
        buf.append(_TAG_FLOAT)
        buf += struct.pack("<d", obj)
    elif isinstance(obj, str):
        raw = obj.encode()
        buf.append(_TAG_STR)
        buf += struct.pack("<I", len(raw))
        buf += raw
    elif isinstance(obj, (bytes, bytearray)):
        buf.append(_TAG_BYTES)
        buf += struct.pack("<I", len(obj))
        buf += obj
    elif isinstance(obj, (list, tuple)):
        buf.append(_TAG_LIST)
        buf += struct.pack("<I", len(obj))
        for v in obj:
            _enc(v, buf)
    elif isinstance(obj, dict):
        buf.append(_TAG_DICT)
        buf += struct.pack("<I", len(obj))
        for k, v in obj.items():
            _enc(str(k), buf)
            _enc(v, buf)
    else:
        raise TypeError(f"cannot serialize {type(obj)}")


def decode(raw: bytes) -> Any:
    obj, _ = _dec(raw, 0)
    return obj


def _dec(raw: bytes, off: int):
    tag = raw[off]
    off += 1
    if tag == _TAG_NONE:
        return None, off
    if tag == _TAG_INT:
        return struct.unpack_from("<q", raw, off)[0], off + 8
    if tag == _TAG_FLOAT:
        return struct.unpack_from("<d", raw, off)[0], off + 8
    if tag == _TAG_STR:
        n = struct.unpack_from("<I", raw, off)[0]
        off += 4
        return raw[off : off + n].decode(), off + n
    if tag == _TAG_BYTES:
        n = struct.unpack_from("<I", raw, off)[0]
        off += 4
        return bytes(raw[off : off + n]), off + n
    if tag == _TAG_LIST:
        n = struct.unpack_from("<I", raw, off)[0]
        off += 4
        out = []
        for _ in range(n):
            v, off = _dec(raw, off)
            out.append(v)
        return out, off
    if tag == _TAG_DICT:
        n = struct.unpack_from("<I", raw, off)[0]
        off += 4
        out = {}
        for _ in range(n):
            k, off = _dec(raw, off)
            v, off = _dec(raw, off)
            out[k] = v
        return out, off
    raise ValueError(f"corrupt wire tag {tag}")


class SerialChannel:
    """Copy-based RPC endpoint: args serialized into a message buffer.

    ``msg_capacity`` bounds a single message (like gRPC's max message
    size). A background listen thread mirrors the zero-copy channel's
    busy-wait loop so RTT comparisons are apples-to-apples.
    """

    R_EMPTY, R_REQ, R_DONE, R_ERR = 0, 1, 2, 3

    def __init__(self, msg_capacity: int = 1 << 20,
                 link_latency_us: float = 0.0):
        self.functions: Dict[int, Callable[[Any], Any]] = {}
        self._req = bytearray(msg_capacity)
        self._resp = bytearray(msg_capacity)
        self._req_len = 0
        self._resp_len = 0
        self._fn_id = 0
        self._state = self.R_EMPTY
        self._stop = threading.Event()
        self.link_latency_us = link_latency_us
        self.bytes_sent = 0
        self.n_calls = 0

    def add(self, fn_id: int, fn: Callable[[Any], Any]) -> None:
        self.functions[fn_id] = fn

    def call(self, fn_id: int, obj: Any, timeout: float = 10.0) -> Any:
        wire = encode(obj)  # serialize
        if len(wire) > len(self._req):
            raise ChannelError("message too large")
        self._req[: len(wire)] = wire  # copy onto the "network"
        self._req_len = len(wire)
        self._fn_id = fn_id
        self.bytes_sent += len(wire)
        if self.link_latency_us:
            time.sleep(self.link_latency_us * 1e-6)
        self._state = self.R_REQ
        deadline = time.monotonic() + timeout
        while self._state == self.R_REQ:
            if time.monotonic() > deadline:
                raise ChannelError("serial RPC timeout")
            time.sleep(0)  # GIL yield — same spin discipline as rpcool
        if self._state == self.R_ERR:
            self._state = self.R_EMPTY
            raise ChannelError("remote error")
        if self.link_latency_us:
            time.sleep(self.link_latency_us * 1e-6)
        out = decode(bytes(self._resp[: self._resp_len]))  # deserialize
        self._state = self.R_EMPTY
        self.n_calls += 1
        return out

    def serve_once(self) -> int:
        if self._state != self.R_REQ:
            return 0
        try:
            obj = decode(bytes(self._req[: self._req_len]))  # deserialize
            ret = self.functions[self._fn_id](obj)
            wire = encode(ret)  # serialize the reply
            self._resp[: len(wire)] = wire
            self._resp_len = len(wire)
            self.bytes_sent += len(wire)
            self._state = self.R_DONE
        except Exception:
            self._state = self.R_ERR
        return 1

    def listen_in_thread(self) -> threading.Thread:
        def loop():
            while not self._stop.is_set():
                if not self.serve_once():
                    time.sleep(0)  # GIL yield between idle polls
        t = threading.Thread(target=loop, daemon=True)
        t.start()
        return t

    def stop(self) -> None:
        self._stop.set()
