"""Declarative service layer — named methods over the typed data plane.

RPCool's client API (paper §5) is channels + in-flight RPCs; what a
*programmer* wants on top is a service: named methods with options, a
client proxy, futures. This module is that surface — a thin, fully
declarative layer over ``conn.invoke`` / ``conn.invoke_async`` that
leaves the raw integer ``fn_id`` API intact underneath as the documented
low-level escape hatch.

Server::

    @service
    class KV:
        def get(self, ctx, key):            # default options
            return self.store.get(key)

        @method(sealed=True, sandboxed=True, deadline=2.0)
        def put(self, ctx, key, val):
            self.store[key] = val

    channel.serve(KV())                     # registers every method

Client::

    stub = router.stub("/pod0/kv", KV, pid=7)   # or ServiceStub(conn, KV)
    stub.put("k", 1)                            # sync typed invoke
    f = stub.get.future("k")                    # pipelined RpcFuture
    gather([f, stub.get.future("j")])           # out-of-order drain

Method *names* map to **stable fn ids**: a hash of ``service.method``
pinned into the upper half of the u32 fn space, so ids survive method
reordering/insertion and never collide with hand-wired small integers.
Per-method options: ``sealed``/``sandboxed`` (the §4.5/§4.4 protections),
``byval`` (force copy semantics — the failover-retry-safe form),
``deadline`` (seconds of budget, propagated into the descriptor),
``retry`` (client retries across failover for retry-safe calls).

Both stub dispatch and handler dispatch run through a small interceptor
chain (`intercept(call, proceed)`); ``StatsInterceptor``,
``DeadlineEnforcer`` and ``RetryInterceptor`` are the built-ins.
"""

from __future__ import annotations

import inspect
import random
import time
import zlib
from typing import Callable, Dict, List, Optional, Tuple

from .channel import _now_us
from .errors import ChannelError, DeadlineExceeded

# service fn ids live in [0x4000_0000, 0x7FFF_FFFF]: stable hashes that
# can never collide with hand-wired small integer fn ids (the escape
# hatch keeps the bottom of the space)
_FN_BASE = 0x4000_0000
_FN_MASK = 0x3FFF_FFFF


def stable_fn_id(service_name: str, method_name: str) -> int:
    """Deterministic fn id for ``service.method`` — stable across method
    reordering, insertion, and processes (it is a pure name hash)."""
    key = f"{service_name}.{method_name}".encode()
    return _FN_BASE | (zlib.crc32(key) & _FN_MASK)


class MethodSpec:
    """One method's wire identity + per-method options."""

    __slots__ = ("name", "fn_id", "sealed", "sandboxed", "byval",
                 "deadline", "retry", "streaming", "byref")

    def __init__(self, name: str, fn_id: int, sealed: bool = False,
                 sandboxed: bool = False, byval: bool = False,
                 deadline: Optional[float] = None, retry: int = 0,
                 streaming: bool = False, byref: bool = False):
        self.name = name
        self.fn_id = fn_id
        self.sealed = sealed
        self.sandboxed = sandboxed
        self.byval = byval
        self.deadline = deadline
        self.retry = retry
        self.streaming = streaming
        self.byref = byref

    def __repr__(self) -> str:  # pragma: no cover
        return (f"<MethodSpec {self.name} fn_id=0x{self.fn_id:08x} "
                f"sealed={self.sealed} sandboxed={self.sandboxed} "
                f"byval={self.byval} deadline={self.deadline} "
                f"retry={self.retry} streaming={self.streaming} "
                f"byref={self.byref}>")


def method(fn=None, *, fn_id: Optional[int] = None, sealed: bool = False,
           sandboxed: bool = False, byval: bool = False,
           deadline: Optional[float] = None, retry: int = 0,
           streaming: bool = False, byref: bool = False):
    """Set a service method's per-method options. Usable bare
    (``@method``) or parameterized (``@method(sealed=True)``). Every
    public method of a ``@service`` class is exported either way —
    undecorated methods get the defaults; underscore-prefixed methods
    stay private helpers. ``streaming=True`` declares a generator
    handler: clients consume it with ``stub.m.stream(...)`` (or drain it
    to a list with a plain sync call). ``byref=True`` declares pool-page
    reference arguments: at dispatch, any argument exposing
    ``__byref_resolve__(conn)`` (e.g. ``serving.kv_pool.PoolPages``) is
    resolved against the route — same-pod calls pass the raw page
    indices, cross-pod calls bulk-migrate the pages first and pass the
    destination indices."""
    def deco(f):
        f.__rpc_method__ = dict(fn_id=fn_id, sealed=sealed,
                                sandboxed=sandboxed, byval=byval,
                                deadline=deadline, retry=retry,
                                streaming=streaming, byref=byref)
        return f
    return deco(fn) if fn is not None else deco


class ServiceDef:
    """A named bundle of MethodSpecs — what ``@service`` attaches to the
    class, what ``Channel.serve`` registers, what a stub proxies."""

    def __init__(self, name: str, methods: Dict[str, MethodSpec]):
        self.name = name
        self.methods = methods
        by_id: Dict[int, str] = {}
        for spec in methods.values():
            other = by_id.get(spec.fn_id)
            if other is not None:
                raise ChannelError(
                    f"service {name!r}: methods {other!r} and "
                    f"{spec.name!r} collide on fn_id 0x{spec.fn_id:08x} "
                    "— pin one with @method(fn_id=...)")
            by_id[spec.fn_id] = spec.name

    # -- server half -----------------------------------------------------
    def serve(self, channel, instance, interceptors=()) -> None:
        """Register every method as a typed handler on ``channel`` (a
        ``Channel`` or a ``FallbackConnection`` — anything with
        ``add_typed``), dispatching through the server interceptor
        chain. An ``AdmissionInterceptor`` in the list is wired to the
        transport's pre-dispatch gate instead of the per-handler chain:
        shedding must cost one descriptor word, never an unmarshal or a
        handler (§5.4)."""
        chain = []
        for icpt in interceptors:
            if isinstance(icpt, AdmissionInterceptor):
                channel.admission = icpt
            else:
                chain.append(icpt)
        chain = tuple(chain)
        for spec in self.methods.values():
            channel.add_typed(spec.fn_id,
                              self._make_handler(instance, spec, chain))

    def _make_handler(self, instance, spec: MethodSpec, interceptors):
        bound = getattr(instance, spec.name)
        svc = self.name

        def final(call: "ServerCall"):
            return bound(call.ctx, *call.args)

        run = _build_chain(interceptors, final)

        def handler(ctx, view):
            # unpack the top-level tuple only: scalars unwrap, nested
            # containers stay lazy ArgViews — handlers keep the
            # touch-only-what-you-dereference property
            args = [view[i] for i in range(len(view))]
            return run(ServerCall(svc, spec, ctx, args))

        handler.__name__ = f"{svc}.{spec.name}"
        return handler

    # -- client half -----------------------------------------------------
    def stub(self, conn, interceptors=()) -> "ServiceStub":
        return ServiceStub(conn, self, interceptors)

    def __repr__(self) -> str:  # pragma: no cover
        return f"<ServiceDef {self.name} methods={sorted(self.methods)}>"


def service(cls=None, *, name: Optional[str] = None):
    """Class decorator: derive a ``ServiceDef`` from the class's methods
    and attach it as ``cls.__service_def__``."""
    def deco(klass):
        svc_name = name or klass.__name__
        # definition order, subclasses overriding base methods
        funcs: Dict[str, Callable] = {}
        for k in reversed(klass.__mro__[:-1]):   # skip object
            for nm, fn in vars(k).items():
                if inspect.isfunction(fn) and not nm.startswith("_"):
                    funcs[nm] = fn
        exported = funcs
        if not exported:
            raise ChannelError(
                f"@service class {klass.__name__} exports no methods")
        methods = {}
        for nm, fn in exported.items():
            opts = getattr(fn, "__rpc_method__", None) or {}
            fid = opts.get("fn_id")
            methods[nm] = MethodSpec(
                nm,
                fid if fid is not None else stable_fn_id(svc_name, nm),
                sealed=opts.get("sealed", False),
                sandboxed=opts.get("sandboxed", False),
                byval=opts.get("byval", False),
                deadline=opts.get("deadline"),
                retry=opts.get("retry", 0),
                streaming=opts.get("streaming", False),
                byref=opts.get("byref", False))
        klass.__service_def__ = ServiceDef(svc_name, methods)
        return klass
    return deco(cls) if cls is not None else deco


def service_def(obj) -> ServiceDef:
    """Resolve anything service-shaped — a ``ServiceDef``, a ``@service``
    class, or an instance of one — to its ``ServiceDef``."""
    if isinstance(obj, ServiceDef):
        return obj
    sdef = getattr(obj, "__service_def__", None)
    if sdef is None:
        raise ChannelError(
            f"{obj!r} is not a service (decorate the class with @service "
            "or pass a ServiceDef)")
    return sdef


# ---------------------------------------------------------------------------
# the interceptor chain (shared client/server machinery)
# ---------------------------------------------------------------------------
class ClientCall:
    """What a client interceptor sees for one stub dispatch."""

    __slots__ = ("service", "spec", "args", "kwargs", "is_future", "conn",
                 "is_stream")

    def __init__(self, svc: str, spec: MethodSpec, args: Tuple,
                 kwargs: dict, is_future: bool, conn,
                 is_stream: bool = False):
        self.service = svc
        self.spec = spec
        self.args = args
        self.kwargs = kwargs
        self.is_future = is_future
        self.conn = conn
        self.is_stream = is_stream

    @property
    def method(self) -> str:
        return self.spec.name


class ServerCall:
    """What a server interceptor sees for one handler dispatch."""

    __slots__ = ("service", "spec", "ctx", "args")

    def __init__(self, svc: str, spec: MethodSpec, ctx, args: List):
        self.service = svc
        self.spec = spec
        self.ctx = ctx
        self.args = args

    @property
    def method(self) -> str:
        return self.spec.name


def _build_chain(interceptors, final):
    """Fold ``interceptors`` around ``final`` once, at registration —
    dispatch walks plain closures, no per-call list handling."""
    run = final
    for icpt in reversed(tuple(interceptors)):
        def run(call, _icpt=icpt, _next=run):
            return _icpt.intercept(call, lambda: _next(call))
    return run


class Interceptor:
    """Base/no-op interceptor: override ``intercept`` and either return
    ``proceed()`` (continue the chain) or short-circuit/raise."""

    def intercept(self, call, proceed):
        return proceed()


class StatsInterceptor(Interceptor):
    """Per-method call/error/latency accounting; usable on either side
    of the wire (hook the same instance into stub and serve to compare
    client-observed vs server-side time)."""

    def __init__(self):
        self.calls: Dict[str, int] = {}
        self.errors: Dict[str, int] = {}
        self.total_us: Dict[str, float] = {}
        # live dispatch gauge (drops back on return/raise) — the same
        # in-flight signal the replica balancer keeps per replica
        self.inflight: Dict[str, int] = {}

    def intercept(self, call, proceed):
        key = f"{call.service}.{call.method}"
        t0 = time.perf_counter()
        self.inflight[key] = self.inflight.get(key, 0) + 1
        try:
            return proceed()
        except BaseException:
            self.errors[key] = self.errors.get(key, 0) + 1
            raise
        finally:
            self.inflight[key] -= 1
            self.calls[key] = self.calls.get(key, 0) + 1
            self.total_us[key] = self.total_us.get(key, 0.0) \
                + (time.perf_counter() - t0) * 1e6

    def snapshot(self) -> Dict[str, Dict[str, float]]:
        return {k: {"calls": n, "errors": self.errors.get(k, 0),
                    "mean_us": self.total_us.get(k, 0.0) / n}
                for k, n in self.calls.items()}


class DeadlineEnforcer(Interceptor):
    """Server-side deadline enforcement: refuse to start a handler whose
    descriptor-propagated deadline already lapsed (the ring layer also
    pre-gates this before dispatch; the interceptor re-checks after any
    earlier interceptors spent time). Raising ``DeadlineExceeded`` maps
    to the dedicated E_DEADLINE reply status."""

    def intercept(self, call, proceed):
        dl = getattr(call.ctx, "deadline_us", 0)
        if dl and _now_us() > dl:
            raise DeadlineExceeded(
                f"{call.service}.{call.method}: deadline lapsed before "
                "dispatch")
        return proceed()


class AdmissionInterceptor(Interceptor):
    """Server-side admission control (§5.4): shed load with E_OVERLOAD
    *before* dispatch — a shed request costs one descriptor word, never
    an unmarshal or a handler. Two gates:

    * ``max_in_flight`` — cap on concurrently admitted dispatches of
      this transport (streams stay admitted until their chunk chain
      ends, so on a single-threaded serve loop this bounds streaming
      concurrency).
    * per-client-pid request quotas from the orchestrator's §5.4 quota
      tables (``orch.set_request_quota(pid, per_second)``), enforced as
      a token bucket on the orchestrator's injectable clock.

    Register it like any server interceptor (``channel.serve(inst,
    interceptors=[admission])``); ``ServiceDef.serve`` wires it to the
    transport's pre-dispatch gate rather than the per-handler chain.
    Shed replies carry a suggested retry-after (µs) in the descriptor's
    ret word — the bucket's time-to-one-token for quota sheds,
    ``retry_after_s`` for in-flight sheds."""

    def __init__(self, max_in_flight: Optional[int] = None,
                 orch=None, retry_after_s: float = 0.005,
                 burst: float = 1.0):
        self.max_in_flight = max_in_flight
        self.orch = orch
        self.retry_after_s = retry_after_s
        self.burst = burst            # bucket depth, in seconds of rate
        self.in_flight = 0
        self._buckets: Dict[int, List[float]] = {}  # pid -> [tokens, t]
        self.n_admitted = 0
        self.n_shed_inflight = 0
        self.n_shed_quota = 0

    # -- the transport-facing gate (called before dispatch) --------------
    def admit(self, client_pid: int, fn_id: int) -> Optional[int]:
        """``None`` = admitted (the transport must ``release()`` when
        the dispatch — or the stream it started — completes); otherwise
        the suggested retry-after in µs and the request is shed."""
        if self.max_in_flight is not None and \
                self.in_flight >= self.max_in_flight:
            self.n_shed_inflight += 1
            return max(1, int(self.retry_after_s * 1e6))
        orch = self.orch
        if orch is not None:
            rate = orch.request_quota(client_pid)
            if rate is not None:
                now = orch.clock()
                cap = rate * self.burst
                bucket = self._buckets.get(client_pid)
                if bucket is None:
                    bucket = self._buckets[client_pid] = [cap, now]
                tokens = min(cap, bucket[0] + (now - bucket[1]) * rate)
                if tokens < 1.0:
                    bucket[0], bucket[1] = tokens, now
                    self.n_shed_quota += 1
                    if rate > 0:
                        return max(1, int((1.0 - tokens) / rate * 1e6))
                    return max(1, int(self.retry_after_s * 1e6))
                bucket[0], bucket[1] = tokens - 1.0, now
        self.in_flight += 1
        self.n_admitted += 1
        return None

    def release(self) -> None:
        self.in_flight -= 1


class RetryInterceptor(Interceptor):
    """Client-side retry with capped jittered exponential backoff.

    Re-runs a *retry-safe* sync dispatch on ``ChannelError`` up to the
    method's ``retry`` budget (or this interceptor's default when the
    method sets none). Retry-safe means nothing in the request pins a
    heap: ``byval`` methods always, other methods only when no argument
    is a ``GraphRef``. An ``Overloaded`` failure honors the suggested
    retry-after as a floor on the next pause (§5.4); other channel
    errors follow the exponential schedule.

    Three things never retry: ``DeadlineExceeded`` (the budget is
    gone), a streaming dispatch that already yielded chunks (delivered
    chunks cannot be un-delivered — ``_client_final`` annotates the
    failure with ``chunks_delivered``), and any attempt whose pause
    would overshoot the method deadline's worth of wall time. Futures
    and stream iterators pass through: a routed future already
    re-invokes across failover on settlement."""

    def __init__(self, default_retries: int = 0,
                 backoff_base_s: float = 0.001,
                 backoff_cap_s: float = 0.25,
                 backoff_multiplier: float = 2.0,
                 jitter: float = 0.5,
                 seed: Optional[int] = None,
                 sleep: Callable[[float], None] = time.sleep):
        self.default_retries = default_retries
        self.backoff_base_s = backoff_base_s
        self.backoff_cap_s = backoff_cap_s
        self.backoff_multiplier = backoff_multiplier
        self.jitter = jitter
        self._rng = random.Random(seed)
        self._sleep = sleep

    def intercept(self, call, proceed):
        retries = call.spec.retry or self.default_retries
        if call.is_future or call.is_stream or retries <= 0 or \
                not _retry_safe(call):
            # stream iterators pass through: delivered chunks cannot be
            # un-delivered, so a failed stream is the caller's restart
            return proceed()
        budget = call.kwargs.get("deadline", call.spec.deadline)
        give_up = None if budget is None \
            else time.monotonic() + budget
        delay = self.backoff_base_s
        for attempt in range(retries + 1):
            try:
                return proceed()
            except DeadlineExceeded:
                raise
            except ChannelError as e:
                if attempt == retries:
                    raise
                if getattr(e, "chunks_delivered", 0):
                    # a buffered streaming dispatch failed after
                    # yielding: a replay would duplicate the prefix
                    raise
                pause = min(
                    delay * (1.0 + self.jitter * self._rng.random()),
                    self.backoff_cap_s)
                retry_after = getattr(e, "retry_after_s", 0.0)
                if retry_after:
                    pause = max(pause, retry_after)
                if give_up is not None and \
                        time.monotonic() + pause >= give_up:
                    # the method deadline's wall budget is spent: a
                    # retry could not complete inside it
                    raise
                self._sleep(pause)
                delay = min(delay * self.backoff_multiplier,
                            self.backoff_cap_s)


def _retry_safe(call: ClientCall) -> bool:
    if call.spec.byval:
        return True
    from .marshal import GraphRef
    return not any(isinstance(a, GraphRef) for a in call.args)


# ---------------------------------------------------------------------------
# the client proxy
# ---------------------------------------------------------------------------
class StubMethod:
    """One method proxy: ``stub.get(k)`` is a sync typed invoke,
    ``stub.get.future(k)`` a pipelined one, ``stub.get.stream(k)`` the
    chunk iterator of a ``streaming=True`` method. Per-call overrides:
    ``timeout``, ``deadline``, ``inline`` (sync/stream), ``window``
    (stream only)."""

    __slots__ = ("_conn", "_spec", "_run", "_svc", "spec")

    def __init__(self, conn, svc: str, spec: MethodSpec, interceptors):
        self._conn = conn
        self._spec = spec
        self.spec = spec   # public: introspection / tests
        self._svc = svc
        self._run = _build_chain(interceptors, _client_final)

    def __call__(self, *args, **overrides):
        return self._run(ClientCall(self._svc, self._spec, args,
                                    overrides, False, self._conn))

    def future(self, *args, **overrides):
        if self._spec.streaming:
            raise ChannelError(
                f"{self._svc}.{self._spec.name} is streaming — consume "
                "it with .stream(...) (or a sync call to buffer it)")
        overrides.pop("inline", None)   # futures never run inline
        return self._run(ClientCall(self._svc, self._spec, args,
                                    overrides, True, self._conn))

    def stream(self, *args, **overrides):
        """Server-push streaming dispatch: returns the route-appropriate
        ``RpcStream`` / ``FallbackRpcStream`` / ``RoutedRpcStream``."""
        if not self._spec.streaming:
            raise ChannelError(
                f"{self._svc}.{self._spec.name} is not a streaming "
                "method (declare it with @method(streaming=True))")
        return self._run(ClientCall(self._svc, self._spec, args,
                                    overrides, False, self._conn,
                                    is_stream=True))


def _client_final(call: ClientCall):
    """The innermost client dispatch: method options → invoke kwargs →
    the route-appropriate typed entry point."""
    spec = call.spec
    conn = call.conn
    if spec.byref:
        # pool-page reference arguments resolve against the route the
        # connection actually took: pointer-pass in pod, one bulk
        # scope_copy migration (then destination indices) across pods.
        # Resolution happens per dispatch, so a retry after a failover
        # re-resolves against the replica's pod.
        call.args = tuple(
            a.__byref_resolve__(conn)
            if hasattr(a, "__byref_resolve__") else a
            for a in call.args)
    kw = dict(call.kwargs)
    if spec.sealed:
        kw.setdefault("sealed", True)
    if spec.sandboxed:
        kw.setdefault("sandboxed", True)
    if spec.deadline is not None:
        kw.setdefault("deadline", spec.deadline)
    if call.is_stream or (spec.streaming and not call.is_future):
        args = call.args
        if spec.byval:
            from .marshal import _args_to_plain
            args = tuple(_args_to_plain(args))
        stream = conn.invoke_stream(spec.fn_id, *args, **kw)
        if call.is_stream:
            return stream
        # sync dispatch of a streaming method buffers the whole chain —
        # the baseline arm of the TTFT comparison, and a convenience.
        # A mid-chain failure is annotated with the delivered-chunk
        # count: retry layers must never replay a partial stream.
        out = []
        try:
            for v in stream:
                out.append(v)
        except ChannelError as e:
            if out:
                e.chunks_delivered = len(out)
            raise
        return out
    if call.is_future:
        args = call.args
        if spec.byval:
            # byval's contract is copy semantics — nothing in the request
            # may pin a heap. Futures honor it by snapshotting GraphRef
            # args to plain values at dispatch, which also keeps the
            # routed future failover-retryable.
            from .marshal import _args_to_plain
            args = tuple(_args_to_plain(args))
        return conn.invoke_async(spec.fn_id, *args, **kw)
    if spec.byval:
        serialized = getattr(conn, "invoke_serialized", None)
        if serialized is not None:
            return serialized(spec.fn_id, *call.args, **kw)
        # a bare FallbackConnection is by-value natively
    return conn.invoke(spec.fn_id, *call.args, **kw)


class ServiceStub:
    """Client proxy for a service over ANY connection type — plain CXL
    ``Connection``, ``FallbackConnection``, or a ``RoutedConnection``
    (same-pod/cross-pod/failover, §5.6: identical surface). Method
    proxies are attributes; ``connection`` / ``close`` are the only
    reserved names."""

    def __init__(self, conn, sdef: ServiceDef, interceptors=()):
        icpts = tuple(interceptors)
        if not any(isinstance(i, RetryInterceptor) for i in icpts):
            # method-level `retry=` works out of the box; an explicit
            # RetryInterceptor in `interceptors` takes over the policy
            icpts = icpts + (RetryInterceptor(),)
        self._conn = conn
        self._def = sdef
        self._methods = {
            nm: StubMethod(conn, sdef.name, spec, icpts)
            for nm, spec in sdef.methods.items()
        }

    def __getattr__(self, name: str) -> StubMethod:
        try:
            return self.__dict__["_methods"][name]
        except KeyError:
            raise AttributeError(
                f"service {self._def.name!r} has no method {name!r}")

    @property
    def connection(self):
        """The underlying connection — the raw escape hatch."""
        return self._conn

    @property
    def definition(self) -> ServiceDef:
        return self._def

    def close(self) -> None:
        self._conn.close()

    def __repr__(self) -> str:  # pragma: no cover
        return (f"<ServiceStub {self._def.name} over "
                f"{type(self._conn).__name__}>")
