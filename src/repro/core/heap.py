"""SharedHeap — the RPCool shared-memory heap (§4.1, §5.1).

A heap is a fixed array of fixed-size pages. On real hardware this is a CXL
memory region mapped at an orchestrator-assigned, cluster-unique address; on
a TPU pod it is a resident device pool (e.g. the paged KV cache) whose page
layout is identical on every host, plus this host-side byte mirror used for
pointer-rich object storage (containers, document stores, RPC descriptors).

Page metadata kept per page:

* ``state``      FREE / USED
* ``owner``      connection id of the allocator (0 == unowned/daemon)
* ``perm``       permission word: bit SEALED ⇒ read-only for the sealing
                 process (the paper's PTE write-protect), bit NOACCESS ⇒
                 unmapped for everyone but the daemon.
* ``key``        MPK protection-key analogue assigned by the sandbox manager.

Permission changes bump ``perm_epoch`` — the analogue of a TLB shootdown.
Batched seal release (§5.3) exists precisely to amortize these bumps, and
the benchmark harness measures that amortization for real.

The allocator is a first-fit extent allocator over pages: scopes (§5.1)
require *contiguous* page ranges, so a bump/bitmap allocator is not enough.
"""

from __future__ import annotations

import threading
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np

from . import addr as gaddr
from .errors import (
    AllocationError,
    InvalidPointer,
    SealedPageError,
)

# page state
FREE = 0
USED = 1

# permission bits
PERM_SEALED = 1 << 0   # write-protected for the sealing (sender) process
PERM_NOACCESS = 1 << 1  # unmapped (daemon-only)

DEFAULT_PAGE_SIZE = 4096


@dataclass
class Extent:
    start: int
    count: int

    @property
    def end(self) -> int:
        return self.start + self.count


class SharedHeap:
    """A shared-memory heap with page-granular permissions."""

    def __init__(
        self,
        heap_id: int,
        num_pages: int,
        page_size: int = DEFAULT_PAGE_SIZE,
        name: str = "",
        sanitize: Optional[bool] = None,
    ):
        if num_pages <= 0 or num_pages > gaddr.MAX_PAGES:
            raise ValueError(f"num_pages out of range: {num_pages}")
        self.heap_id = heap_id
        self.num_pages = num_pages
        self.page_size = page_size
        self.name = name or f"heap{heap_id}"

        # The byte space. One contiguous buffer == the CXL region.
        self.buf = np.zeros(num_pages * page_size, dtype=np.uint8)
        # Cached 'B'-format memoryview of the byte space: slice-assigning
        # into it from bytes/bytearray/memoryview is a single C memcpy,
        # with no intermediate Python-level copy.
        self._bytes = self.buf.data

        self.state = np.full(num_pages, FREE, dtype=np.uint8)
        self.owner = np.zeros(num_pages, dtype=np.int32)
        self.perm = np.zeros(num_pages, dtype=np.uint8)
        # Which process a seal protects against (the sender); 0 = none.
        self.seal_holder = np.zeros(num_pages, dtype=np.int64)
        self.key = np.zeros(num_pages, dtype=np.int16)  # MPK key per page

        # TLB-shootdown analogue: every permission flip visible to other
        # threads/devices costs an epoch bump + (if attached) a device sync.
        self.perm_epoch = 0

        self._free: List[Extent] = [Extent(0, num_pages)]
        self._lock = threading.RLock()

        # Optional device mirror of the permission word (consumed by
        # sandboxed kernels). Lazily attached by serving/kv_pool.
        # When ``eager`` the mirror is re-pushed on every epoch bump —
        # that push IS the TLB-shootdown analogue, and batched release
        # exists to amortize it (§5.3).
        self._device_perm = None
        self._device_dirty = False
        self._eager_sync = False

        # ShmCheck sanitizer (analysis/): ``sanitize`` True forces
        # tracing, False opts out, None attaches only when a session is
        # active or REPRO_SANITIZE is set. When off, the one reference
        # below is the entire cost of the instrumentation.
        self._tracer = None
        if sanitize is not False:
            from ..analysis.runtime import maybe_attach
            self._tracer = maybe_attach(self, sanitize)

    # ------------------------------------------------------------------
    # allocation
    # ------------------------------------------------------------------
    def alloc_pages(self, count: int, owner: int = 0) -> int:
        """First-fit contiguous allocation. Returns the starting page."""
        if count <= 0:
            raise AllocationError(f"bad page count {count}")
        with self._lock:
            for i, ext in enumerate(self._free):
                if ext.count >= count:
                    start = ext.start
                    if ext.count == count:
                        self._free.pop(i)
                    else:
                        ext.start += count
                        ext.count -= count
                    self.state[start : start + count] = USED
                    self.owner[start : start + count] = owner
                    self.perm[start : start + count] = 0
                    self.seal_holder[start : start + count] = 0
                    if self._tracer is not None:
                        self._tracer.on_alloc(self, start, count, owner)
                    return start
            raise AllocationError(
                f"{self.name}: cannot allocate {count} contiguous pages "
                f"({self.free_pages()} free, fragmented)"
            )

    def free_extent(self, start: int, count: int) -> None:
        with self._lock:
            if np.any(self.state[start : start + count] == FREE):
                raise InvalidPointer(
                    f"double free of pages [{start},{start + count}) in {self.name}"
                )
            self.state[start : start + count] = FREE
            self.owner[start : start + count] = 0
            self.perm[start : start + count] = 0
            self.seal_holder[start : start + count] = 0
            # freeing drops the MPK key assignment (unmap ⇒ no key): a
            # cached sandbox binding over these pages is void from here —
            # SandboxManager._still_valid sees the cleared key even if
            # the range is immediately reallocated to someone else
            self.key[start : start + count] = 0
            self._insert_free(Extent(start, count))
            if self._tracer is not None:
                self._tracer.on_free(self, start, count)

    def _insert_free(self, ext: Extent) -> None:
        # keep the free list sorted + coalesced
        free = self._free
        lo, hi = 0, len(free)
        while lo < hi:
            mid = (lo + hi) // 2
            if free[mid].start < ext.start:
                lo = mid + 1
            else:
                hi = mid
        free.insert(lo, ext)
        # coalesce with neighbours
        if lo + 1 < len(free) and free[lo].end == free[lo + 1].start:
            free[lo].count += free[lo + 1].count
            free.pop(lo + 1)
        if lo > 0 and free[lo - 1].end == free[lo].start:
            free[lo - 1].count += free[lo].count
            free.pop(lo)

    def free_pages(self) -> int:
        return int(sum(e.count for e in self._free))

    def used_pages(self) -> int:
        return self.num_pages - self.free_pages()

    def used_bytes(self) -> int:
        return self.used_pages() * self.page_size

    # ------------------------------------------------------------------
    # permissions (seal substrate — SealManager drives this)
    # ------------------------------------------------------------------
    def protect_range(self, start: int, count: int, holder: int) -> None:
        """Write-protect pages for ``holder`` (the sender). One epoch bump."""
        with self._lock:
            sl = slice(start, start + count)
            if np.any(self.state[sl] == FREE):
                raise InvalidPointer("sealing unallocated pages")
            self.perm[sl] |= PERM_SEALED
            self.seal_holder[sl] = holder
            self._bump_epoch()
            if self._tracer is not None:
                self._tracer.on_protect(self, start, count, holder)

    def unprotect_range(self, start: int, count: int) -> None:
        with self._lock:
            sl = slice(start, start + count)
            self.perm[sl] &= ~np.uint8(PERM_SEALED)
            self.seal_holder[sl] = 0
            self._bump_epoch()
            if self._tracer is not None:
                self._tracer.on_unprotect(self, [(start, count)])

    def unprotect_ranges(self, ranges: List[Tuple[int, int]]) -> None:
        """Batched release — MANY ranges, ONE epoch bump (§5.3)."""
        with self._lock:
            for start, count in ranges:
                sl = slice(start, start + count)
                self.perm[sl] &= ~np.uint8(PERM_SEALED)
                self.seal_holder[sl] = 0
            self._bump_epoch()
            if self._tracer is not None:
                self._tracer.on_unprotect(self, ranges)

    def _bump_epoch(self) -> None:
        self.perm_epoch += 1
        self._device_dirty = True
        if self._eager_sync:
            self._sync_device()

    # ------------------------------------------------------------------
    # byte access (checked loads/stores — what MMU+MPK do in hardware)
    # ------------------------------------------------------------------
    def _check_addr(self, a: int, nbytes: int) -> Tuple[int, int]:
        if gaddr.is_null(a):
            raise InvalidPointer("NULL dereference")
        if gaddr.heap_of(a) != self.heap_id:
            raise InvalidPointer(
                f"addr heap {gaddr.heap_of(a)} != {self.heap_id} ({self.name})"
            )
        off = gaddr.linear(a, self.page_size)
        if off + nbytes > self.num_pages * self.page_size:
            raise InvalidPointer(f"addr+{nbytes} past end of {self.name}")
        return off, off + nbytes

    @staticmethod
    def _payload_nbytes(data) -> int:
        if isinstance(data, (np.ndarray, memoryview)):
            return data.nbytes
        return len(data)

    def _store(self, lo: int, hi: int, data) -> None:
        """Copy ``data`` into heap bytes with exactly one memcpy — no
        intermediate ``bytes()`` materialization (the historical
        ``np.frombuffer(bytes(data))`` path copied every payload twice)."""
        if isinstance(data, np.ndarray):
            if data.dtype != np.uint8 or data.ndim != 1:
                data = np.ascontiguousarray(data).reshape(-1).view(np.uint8)
            self.buf[lo:hi] = data
        elif isinstance(data, memoryview):
            if data.format != "B" or data.ndim != 1:
                try:
                    data = data.cast("B")
                except TypeError:  # non-contiguous: flattening copy
                    data = bytes(data)
            self._bytes[lo:hi] = data
        else:  # bytes | bytearray
            self._bytes[lo:hi] = data

    def write(self, a: int,
              data: bytes | bytearray | memoryview | np.ndarray,
              pid: int = 0) -> None:
        lo, hi = self._check_addr(a, self._payload_nbytes(data))
        p0, p1 = lo // self.page_size, (hi - 1) // self.page_size + 1
        if p1 - p0 == 1:  # hot path: single-page access, scalar checks
            if self.state[p0] == FREE:
                raise InvalidPointer(f"write to freed page in {self.name}")
            if pid and (self.perm[p0] & PERM_SEALED) and \
                    self.seal_holder[p0] == pid:
                raise SealedPageError(
                    f"pid {pid} writing sealed page in {self.name} "
                    f"(RPC in flight — §4.5)"
                )
        else:
            sl = slice(p0, p1)
            if np.any(self.state[sl] == FREE):
                raise InvalidPointer(f"write to freed page in {self.name}")
            if pid and np.any(
                (self.perm[sl] & PERM_SEALED != 0)
                & (self.seal_holder[sl] == pid)
            ):
                raise SealedPageError(
                    f"pid {pid} writing sealed page in {self.name} "
                    f"(RPC in flight — §4.5)"
                )
        self._store(lo, hi, data)
        if self._tracer is not None:
            self._tracer.on_write(self, lo, hi, pid)

    def read(self, a: int, nbytes: int) -> np.ndarray:
        lo, hi = self._check_addr(a, nbytes)
        p0, p1 = lo // self.page_size, (hi - 1) // self.page_size + 1
        if p1 - p0 == 1:
            if self.state[p0] == FREE:
                raise InvalidPointer(f"read of freed page in {self.name}")
        elif np.any(self.state[p0:p1] == FREE):
            raise InvalidPointer(f"read of freed page in {self.name}")
        if self._tracer is not None:
            self._tracer.on_read(self, lo, hi)
        return self.buf[lo:hi]

    def write_fast(self, a: int,
                   data: bytes | bytearray | memoryview | np.ndarray) -> None:
        """Unchecked-permissions write for freshly-allocated private
        scopes (builder hot path): bounds only — never use on pages that
        may be sealed or foreign (the checked ``write`` is the default)."""
        lo = gaddr.linear(a, self.page_size)
        hi = lo + self._payload_nbytes(data)
        if hi > self.num_pages * self.page_size:
            raise InvalidPointer(f"write past end of {self.name}")
        self._store(lo, hi, data)
        if self._tracer is not None:
            self._tracer.on_write(self, lo, hi, 0)

    def addr_of_page(self, page: int, offset: int = 0) -> int:
        return gaddr.pack(self.heap_id, page, offset)

    # ------------------------------------------------------------------
    # device mirror (perm bits consumed by sandboxed Pallas kernels)
    # ------------------------------------------------------------------
    def attach_device_perm(self, eager: bool = False) -> None:
        """Mirror the perm word on device. ``eager`` re-pushes the mirror on
        every epoch bump — the physical cost a seal release pays (the TLB
        shootdown / key-reassignment analogue) and what batched release
        amortizes."""
        self._eager_sync = eager
        self._sync_device()

    def _sync_device(self) -> None:
        import jax  # lazy: core stays importable without jax
        import jax.numpy as jnp

        self._device_perm = jax.block_until_ready(jnp.asarray(self.perm))
        self._device_dirty = False

    def device_perm(self):
        if self._device_perm is None or self._device_dirty:
            self._sync_device()
        return self._device_perm

    # ------------------------------------------------------------------
    def stats(self) -> Dict[str, int]:
        return {
            "heap_id": self.heap_id,
            "pages": self.num_pages,
            "page_size": self.page_size,
            "used_pages": self.used_pages(),
            "free_pages": self.free_pages(),
            "sealed_pages": int((self.perm & PERM_SEALED != 0).sum()),
            "perm_epoch": self.perm_epoch,
        }

    def __repr__(self) -> str:  # pragma: no cover
        s = self.stats()
        return (
            f"<SharedHeap {self.name} pages={s['used_pages']}/{s['pages']} "
            f"sealed={s['sealed_pages']} epoch={s['perm_epoch']}>"
        )
