"""Scopes — self-contained page ranges for RPC arguments (§4.5, §5.1).

A scope is a dedicated range of contiguous pages within a connection's heap
that holds exactly the data for one RPC. Sealing a scope therefore never
"false-seals" unrelated objects that happen to share a page.

Scopes carry their own bump allocator (`alloc`) and can be ``reset`` for
reuse or ``destroy``ed to return the pages to the heap. ``ScopePool``
(§5.3 "Optimizing Sealing") keeps a pool of pre-created scopes so hot RPC
paths never touch the heap allocator, and batches seal releases.
"""

from __future__ import annotations

from typing import List, Optional

import numpy as np

from . import addr as gaddr
from .errors import AllocationError, InvalidPointer
from .heap import SharedHeap

_ALIGN = 8


def _align(n: int) -> int:
    return (n + _ALIGN - 1) & ~(_ALIGN - 1)


class Scope:
    """A contiguous page range + bump allocator."""

    def __init__(self, heap: SharedHeap, start_page: int, num_pages: int,
                 owner: int = 0):
        self.heap = heap
        self.start_page = start_page
        self.num_pages = num_pages
        self.owner = owner
        self._bump = 0  # byte offset within the scope
        self._live = True
        if heap._tracer is not None:
            heap._tracer.on_scope_create(self)

    # -- geometry ------------------------------------------------------
    @property
    def size_bytes(self) -> int:
        return self.num_pages * self.heap.page_size

    @property
    def base_addr(self) -> int:
        return self.heap.addr_of_page(self.start_page)

    def page_range(self) -> tuple[int, int]:
        return (self.start_page, self.num_pages)

    def contains(self, a: int) -> bool:
        if gaddr.is_null(a) or gaddr.heap_of(a) != self.heap.heap_id:
            return False
        lin = gaddr.linear(a, self.heap.page_size)
        lo = self.start_page * self.heap.page_size
        return lo <= lin < lo + self.size_bytes

    # -- allocation ----------------------------------------------------
    def alloc(self, nbytes: int) -> int:
        """Bump-allocate ``nbytes`` in the scope; returns a GlobalAddr."""
        if self.heap._tracer is not None:
            self.heap._tracer.on_scope_use(self, "alloc")
        if not self._live:
            raise InvalidPointer("allocation in destroyed scope")
        off = _align(self._bump)
        if off + nbytes > self.size_bytes:
            raise AllocationError(
                f"scope overflow: {off}+{nbytes} > {self.size_bytes}"
            )
        self._bump = off + nbytes
        return gaddr.add(self.base_addr, off, self.heap.page_size)

    def write_bytes(self, data: bytes | bytearray | memoryview | np.ndarray,
                    pid: int = 0) -> int:
        """Copy ``data`` into the scope (one memcpy — the heap accepts any
        buffer-protocol payload without an intermediate ``bytes()``)."""
        a = self.alloc(SharedHeap._payload_nbytes(data))
        self.heap.write(a, data, pid=pid)
        return a

    def write_u64(self, values: List[int], pid: int = 0) -> int:
        return self.write_bytes(np.asarray(values, dtype="<u8"), pid)

    def view(self) -> np.ndarray:
        """Raw ndarray view of the scope's bytes (zero-copy fill path)."""
        if self.heap._tracer is not None:
            self.heap._tracer.on_scope_use(self, "view")
        lo = self.start_page * self.heap.page_size
        return self.heap.buf[lo : lo + self.size_bytes]

    def used_bytes(self) -> int:
        return self._bump

    def remaining_bytes(self) -> int:
        """Bytes still allocatable (from the next aligned offset)."""
        return max(0, self.size_bytes - _align(self._bump))

    # -- lifecycle (§5.1) ----------------------------------------------
    def reset(self) -> None:
        """Reuse the scope: all objects allocated within are lost."""
        self._bump = 0

    def destroy(self) -> None:
        if self._live:
            self.heap.free_extent(self.start_page, self.num_pages)
            self._live = False
            if self.heap._tracer is not None:
                self.heap._tracer.on_scope_destroy(self)

    @property
    def live(self) -> bool:
        return self._live

    def __repr__(self) -> str:  # pragma: no cover
        return (
            f"<Scope heap={self.heap.heap_id} pages=[{self.start_page},"
            f"{self.start_page + self.num_pages}) used={self._bump}B>"
        )


def create_scope(heap: SharedHeap, size_bytes: int, owner: int = 0) -> Scope:
    """``Connection::create_scope(size)`` (§5.1)."""
    pages = max(1, (size_bytes + heap.page_size - 1) // heap.page_size)
    start = heap.alloc_pages(pages, owner=owner)
    return Scope(heap, start, pages, owner=owner)


def implicit_scope(conn, nbytes: int, page_size: int) -> Scope:
    """The one implicit-allocation policy behind scope-less ``new_bytes``
    on every transport: consecutive allocations share the connection's
    current implicit scope until it fills, every scope is tracked on the
    connection and returned to the heap at close (scope-less allocations
    historically leaked an untracked single-use scope each)."""
    s = conn._implicit
    if s is None or s.remaining_bytes() < nbytes:
        s = conn.create_scope(max(nbytes or 1, page_size))
        conn._implicit_scopes.append(s)
        conn._implicit = s
    return s


class ScopePool:
    """Pre-created scopes for hot RPC paths + batched seal release (§5.3).

    ``pop`` hands out a reset scope; ``push`` returns it. A scope whose seal
    release was *batched* (deferred) is returned with ``push_sealed`` — it
    stays quarantined until the SealManager flushes the batch, because its
    pages are still write-protected ("batched releases work best when the
    application does not need to modify the sealed arguments until the
    batch is processed", §5.3). If the pool runs dry it forces a flush.
    """

    def __init__(self, heap: SharedHeap, scope_pages: int,
                 max_scopes: int = 8192, owner: int = 0, seals=None):
        self.heap = heap
        self.scope_pages = scope_pages
        self.max_scopes = max_scopes
        self.owner = owner
        self.seals = seals  # Optional[SealManager]
        self._free: List[Scope] = []
        self._pending: List[tuple] = []  # (scope, seal_idx)
        self._created = 0

    def pop(self) -> Scope:
        if not self._free and self._pending:
            self._reclaim(force=False)
        if not self._free and self._created >= self.max_scopes \
                and self._pending:
            # pool dry: pay for a flush now (one epoch) to reclaim scopes
            self.seals.flush()
            self._reclaim(force=False)
        if self._free:
            s = self._free.pop()
            s.reset()
            if self.heap._tracer is not None:
                self.heap._tracer.on_pool_pop(s)
            return s
        if self._created >= self.max_scopes:
            raise AllocationError("scope pool exhausted")
        self._created += 1
        start = self.heap.alloc_pages(self.scope_pages, owner=self.owner)
        return Scope(self.heap, start, self.scope_pages, owner=self.owner)

    def push(self, scope: Scope) -> None:
        if scope.heap is not self.heap or scope.num_pages != self.scope_pages:
            raise InvalidPointer("scope returned to wrong pool")
        self._free.append(scope)
        if self.heap._tracer is not None:
            self.heap._tracer.on_pool_push(scope)

    def push_sealed(self, scope: Scope, seal_idx: int) -> None:
        """Return a scope whose batched seal release is still pending."""
        if self.seals is None:
            raise InvalidPointer("push_sealed on a pool without a SealManager")
        self._pending.append((scope, self.seals.flush_gen))
        if self.heap._tracer is not None:
            self.heap._tracer.on_pool_push(scope)

    def _reclaim(self, force: bool) -> None:
        gen = self.seals.flush_gen
        still = []
        for s, g in self._pending:
            if g < gen:  # queued before the last flush ⇒ released
                self._free.append(s)
            else:
                still.append((s, g))
        self._pending = still

    def drain(self) -> None:
        if self._pending and self.seals is not None:
            self.seals.flush()
            self._reclaim(force=True)
        for s in self._free:
            s.destroy()
        self._free.clear()
        self._created = 0

    @property
    def outstanding(self) -> int:
        return self._created - len(self._free) - len(self._pending)
